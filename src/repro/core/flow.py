"""The paper's optimisation flow (Sec. II-C), generalised to graph IRs.

For each (hardware configuration x fusion grouping) candidate, estimate the
four metrics, reject candidates violating the user constraints, and return
the feasible candidate with minimum energy.  The cross-product is evaluated
as a single jitted/vmapped XLA program
(:func:`repro.core.metrics.evaluate_batch_graph`), which is the JAX-native
realisation of the paper's exhaustive sweep — the benchmark reports
candidates/second.  Groupings are boolean cut vectors over the graph's
edges; chains (``NetworkIR``) are embedded losslessly via
:func:`repro.core.ir.as_graph`.

Two serving-system moves keep the cold path cheap (``benchmarks/
bench_fleet.py``): argument shapes are rounded up to power-of-two *shape
buckets* and evaluated through masked kernels (padded rows exactly inert),
so distinct graphs share one compiled executable instead of each paying
XLA compilation per exact ``(L, E, C)`` signature; and :func:`run_fleet`
stacks many padded graphs along a leading axis to evaluate the whole
``(G, H, C)`` cross-product — the entire model fleet — in one program.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Sequence

import numpy as np
from jax.experimental import enable_x64

from . import fusion
from . import metrics as M
from ..parallel.sharding import hardware_mesh, mesh_fingerprint
from .arch import Constraints, DLAConfig, default_config_space
from .errors import (
    InfeasibleBudgetError,
    InfeasibleConstraintsError,
    PoisonedResultError,
    RetryPolicy,
    TransientFailure,
)
from .ir import (
    GraphIR,
    NetworkIR,
    as_graph,
    bucket_size,
    pad_cuts_batch,
    pad_graph,
)

# Shape-bucket floors: (L, E, C) are rounded up to the next power of two, but
# never below these, so every in-repo workload (VGG-16 18/17, ResNet-18
# 31/38, MobileNet 17/18, MLP block 4/3, encoder-decoder 19/21, residual
# block 4/4) lands in the SAME (32, 64) bucket and one cached executable
# serves the whole model fleet.  The padded rows are exactly inert (masked
# kernels), so bucketing never changes a metric — it only kills recompiles.
NODE_BUCKET_FLOOR = 32
EDGE_BUCKET_FLOOR = 64
CUT_BUCKET_FLOOR = 4


@dataclasses.dataclass(frozen=True)
class FlowResult:
    """One graph's sweep outcome: the argmin (hw, cuts, metrics), the
    candidate/feasibility accounting, timing split, and provenance."""

    best_hw: DLAConfig
    best_cuts: np.ndarray
    best_metrics: M.Metrics
    group_sizes: tuple[int, ...]
    n_candidates: int
    n_feasible: int
    n_pruned: int  # groupings dropped by the SRAM prefilter before the sweep
    compile_seconds: float  # XLA compile paid by this call (0 on cache hit)
    sweep_seconds: float  # the single timed execution
    candidates_per_second: float
    # Provenance of the grouping candidates: "exhaustive" / "pool" /
    # "explicit", or — for groupings="search"/"dp" — the engine that
    # produced the search optimum ("chain_dp" / "frontier_dp" / "beam"),
    # so callers know whether the swept optimum is certified exact.
    search_engine: str = ""
    # (architecture x fusion plan) Pareto front over the feasible sweep,
    # populated when the flow is asked for it (``pareto=True``).
    pareto: "ParetoFront | None" = None
    # Cells the finite guard excluded (None when the sweep was clean).
    quarantine: "QuarantineReport | None" = None

    def describe(self) -> str:
        """One-line summary: best hw, group sizes, and the four metrics."""
        return (
            f"best={self.best_hw.describe()} groups={list(self.group_sizes)} "
            f"BW={self.best_metrics.bandwidth_words/1e6:.2f}M words "
            f"lat={self.best_metrics.latency_cycles/1e6:.2f}M cyc "
            f"E={self.best_metrics.energy_nj/1e6:.2f} mJ "
            f"A={self.best_metrics.area_um2/1e6:.2f} mm^2 "
            f"({self.n_feasible}/{self.n_candidates} feasible, "
            f"{self.n_pruned} pruned, "
            f"{self.candidates_per_second:,.0f} cand/s, "
            f"compile {self.compile_seconds*1e3:.0f} ms, "
            f"groupings={self.search_engine})"
        )


# AOT-compiled evaluator executables keyed by (kernel, argument shapes), so
# a run_flow/run_fleet call executes the sweep exactly once: the first call
# with a new shape signature pays (and reports) the XLA compile, repeats
# reuse the executable and report compile_seconds == 0.  The cache is a
# bounded LRU: a hit refreshes the entry, and at capacity only the
# least-recently-used executable is evicted (never a wholesale clear, which
# would drop every hot executable at once).
_COMPILED_SWEEPS: "collections.OrderedDict[tuple, object]" = (
    collections.OrderedDict()
)
SWEEP_CACHE_CAPACITY = 64
_SWEEP_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}
# One lock covers the OrderedDict *and* its stats dict: the planning
# service's admission path touches the cache from whatever thread submits,
# and an unguarded move_to_end/popitem pair can corrupt the LRU order (or
# the hit/miss/eviction accounting) under interleaving.
_SWEEP_CACHE_LOCK = threading.Lock()


def _sweep_cache_get(key: tuple):
    """LRU lookup: a hit moves the entry to the most-recently-used end."""
    with _SWEEP_CACHE_LOCK:
        exe = _COMPILED_SWEEPS.get(key)
        if exe is not None:
            _COMPILED_SWEEPS.move_to_end(key)
            _SWEEP_CACHE_STATS["hits"] += 1
        return exe


def _sweep_cache_put(key: tuple, exe) -> None:
    """LRU insert: evicts oldest entries only, one at a time, at capacity."""
    with _SWEEP_CACHE_LOCK:
        _SWEEP_CACHE_STATS["misses"] += 1
        while len(_COMPILED_SWEEPS) >= SWEEP_CACHE_CAPACITY:
            _COMPILED_SWEEPS.popitem(last=False)
            _SWEEP_CACHE_STATS["evictions"] += 1
        _COMPILED_SWEEPS[key] = exe


# Mesh component of every cache key.  A sweep compiled for one device
# layout must never be served to another: an 8-device shard_mapped program
# and the single-device program have identical argument shapes, so shapes
# alone cannot tell them apart.
_SINGLE_MESH_KEY = ("single", 1)


def _cache_entry_info(key: tuple) -> dict:
    """{kernel, mesh_axis, device_count} view of one cache key (tolerant of
    synthetic short keys used by unit tests)."""
    kernel = key[0] if key else "?"
    mesh = (
        key[1]
        if len(key) > 1 and isinstance(key[1], tuple) and len(key[1]) >= 2
        else _SINGLE_MESH_KEY
    )
    return {
        "kernel": kernel,
        "mesh_axis": mesh[0],
        "device_count": int(mesh[1]),
    }


def sweep_cache_stats() -> dict:
    """Executable-cache accounting: {size, hits, misses, evictions,
    entries}.  ``misses`` counts XLA compilations actually paid — the fleet
    benchmark asserts a whole multi-model sweep costs exactly one.
    ``entries`` lists each cached executable's {kernel, mesh_axis,
    device_count}, so the device-layout split of the key space is
    observable (a 1-device sweep and an 8-device sweep are distinct
    entries even at identical shapes).  Snapshotted under the cache lock,
    so concurrent readers never see a half-updated accounting."""
    with _SWEEP_CACHE_LOCK:
        return dict(
            _SWEEP_CACHE_STATS,
            size=len(_COMPILED_SWEEPS),
            entries=[_cache_entry_info(k) for k in _COMPILED_SWEEPS],
        )


def clear_sweep_cache() -> None:
    """Drop every cached sweep executable and zero the hit/miss stats."""
    with _SWEEP_CACHE_LOCK:
        _COMPILED_SWEEPS.clear()
        for k in _SWEEP_CACHE_STATS:
            _SWEEP_CACHE_STATS[k] = 0


def _compiled_sweep(
    fn, args, mesh_key: tuple = _SINGLE_MESH_KEY
) -> tuple[object, float]:
    """(executable, compile_seconds_this_call) for a jitted metric kernel.

    Lowered under scoped ``enable_x64`` with float64 numpy arguments, so
    the sweep is exact (bit-identical to the scalar oracles) without
    touching the process-global JAX precision config.  ``mesh_key``
    (:data:`_SINGLE_MESH_KEY` or a sharded mesh fingerprint) is part of
    the cache key: device layout changes the compiled program even at
    identical argument shapes."""
    key = (getattr(fn, "__name__", str(fn)), mesh_key) + tuple(
        (a.shape, str(a.dtype)) for a in args
    )
    exe = _sweep_cache_get(key)
    if exe is not None:
        return exe, 0.0
    t0 = time.perf_counter()
    with enable_x64():
        exe = fn.lower(*args).compile()
    dt = time.perf_counter() - t0
    _sweep_cache_put(key, exe)
    return exe, dt


def _run_sweep(exe, args) -> tuple[np.ndarray, float]:
    """(result, sweep_seconds): one timed execution of an AOT executable
    (inside ``enable_x64`` — the executable's avals are float64)."""
    t1 = time.perf_counter()
    with enable_x64():
        out = np.asarray(exe(*args))
    return out, time.perf_counter() - t1


def _metrics_from_row(row: np.ndarray) -> M.Metrics:
    return M.Metrics(
        bandwidth_words=float(row[0]),
        latency_cycles=float(row[1]),
        energy_nj=float(row[2]),
        area_um2=float(row[3]),
    )


# ---------------------------------------------------------------------------
# Poison quarantine — the finite guard over raw sweep planes
# ---------------------------------------------------------------------------

# Column names of the raw (…, 5) kernel rows, for quarantine provenance.
RAW_COLUMNS = (
    "bandwidth_words",
    "latency_cycles",
    "sram_accesses",
    "pb_accesses",
    "area_um2",
)


@dataclasses.dataclass(frozen=True)
class QuarantinedCell:
    """Provenance of one poisoned sweep cell: which (graph, hw, cut)
    candidate was excluded, which raw column tripped the finite guard,
    the offending value, and why (``nan``/``inf``/``negative``/
    ``overflow`` — overflow meaning above 2^53, where integer word
    counts stop being exact in f64)."""

    graph: int
    hw: int
    cut: int
    column: str
    value: float
    reason: str


@dataclasses.dataclass(frozen=True)
class QuarantineReport:
    """Every cell the finite guard excluded from one sweep's selection.

    Quarantined cells can never win the argmin or enter a Pareto front —
    they are removed from the feasible set *before* selection — but the
    rest of the sweep still answers; only a graph whose ENTIRE candidate
    set is poisoned raises :class:`~repro.core.errors.PoisonedResultError`.
    """

    cells: tuple[QuarantinedCell, ...]

    @property
    def n_cells(self) -> int:
        """Number of quarantined (graph, hw, cut) cells."""
        return len(self.cells)

    def describe(self, limit: int = 8) -> str:
        """Multi-line summary: cell count plus the first ``limit`` cells."""
        lines = [f"quarantined {self.n_cells} poisoned cells"]
        for cell in self.cells[:limit]:
            lines.append(
                f"  (g={cell.graph}, h={cell.hw}, c={cell.cut}) "
                f"{cell.column}={cell.value!r} [{cell.reason}]"
            )
        if self.n_cells > limit:
            lines.append(f"  ... {self.n_cells - limit} more")
        return "\n".join(lines)


def _poison_reason(v: float) -> str:
    """Finite-guard verdict for one offending raw value."""
    if np.isnan(v):
        return "nan"
    if np.isinf(v):
        return "inf"
    if v < 0.0:
        return "negative"
    return "overflow"


def _quarantine_cells(
    raw: np.ndarray,  # (H, C, 5) one graph's raw plane, real rows only
    poison: np.ndarray,  # (H, C) bool, from metrics.poison_mask
    *,
    graph: int,
) -> tuple[QuarantinedCell, ...]:
    """Provenance records for one graph's poisoned cells, naming the first
    offending raw column of each."""
    cells = []
    for h, c in np.argwhere(poison):
        row = raw[h, c]
        bad = ~np.isfinite(row) | (row < 0.0) | (row > M.MAX_EXACT_WORDS)
        k = int(np.flatnonzero(bad)[0])
        v = float(row[k])
        cells.append(
            QuarantinedCell(
                graph=int(graph), hw=int(h), cut=int(c),
                column=RAW_COLUMNS[k], value=v, reason=_poison_reason(v),
            )
        )
    return tuple(cells)


@dataclasses.dataclass(frozen=True)
class ParetoFront:
    """Non-dominated (architecture x fusion plan) points of one workload's
    feasible sweep, minimising (bandwidth, latency, energy, area) jointly —
    the design-space-exploration output the single min-energy point throws
    away.  Points are sorted by (energy, bandwidth, latency, area, h, c);
    exact-duplicate metric rows keep their lowest-index representative
    (:func:`repro.core.metrics.pareto_front_mask`), so the front is
    deterministic and device-count invariant like the argmin."""

    metrics: np.ndarray  # (P, 4) [bw, lat, energy, area]
    hw_indices: np.ndarray  # (P,) into the sweep's config_space
    cut_indices: np.ndarray  # (P,) into the surviving cut batch
    configs: tuple[DLAConfig, ...]  # (P,) the actual design points
    cuts: np.ndarray  # (P, E) the fusion plan of each point
    n_feasible: int  # candidates the front was extracted from
    search_engine: str = ""  # grouping provenance, as FlowResult

    @property
    def size(self) -> int:
        """Number of non-dominated points on the front."""
        return int(self.metrics.shape[0])

    def describe(self, limit: int = 8) -> str:
        """Multi-line summary: front size plus the first ``limit`` rows."""
        lines = [
            f"pareto front: {self.size} of {self.n_feasible} feasible "
            f"(groupings={self.search_engine})"
        ]
        for i in range(min(self.size, limit)):
            bw, lat, e, a = self.metrics[i]
            lines.append(
                f"  {self.configs[i].describe():40s} "
                f"BW={bw/1e6:7.2f}M lat={lat/1e6:7.2f}M "
                f"E={e/1e6:6.2f}mJ A={a/1e6:5.2f}mm^2"
            )
        if self.size > limit:
            lines.append(f"  ... {self.size - limit} more")
        return "\n".join(lines)


def _pareto_front(
    out: np.ndarray,  # (H, C, 4) real candidate rows
    feasible: np.ndarray,  # (H, C) bool
    cuts_batch: np.ndarray,  # (C, E)
    config_space: Sequence[DLAConfig],
    search_engine: str,
) -> ParetoFront:
    """Extract the feasible sweep's Pareto front in deterministic order."""
    idx = np.argwhere(feasible)  # (N, 2) in (h, c) lexicographic order
    rows = out[feasible]  # row-major: matches idx order
    keep = M.pareto_front_mask(rows)
    sel_rows, sel_idx = rows[keep], idx[keep]
    order = np.lexsort(
        (
            sel_idx[:, 1],
            sel_idx[:, 0],
            sel_rows[:, 3],
            sel_rows[:, 1],
            sel_rows[:, 0],
            sel_rows[:, 2],
        )
    )
    sel_rows, sel_idx = sel_rows[order], sel_idx[order]
    return ParetoFront(
        metrics=sel_rows,
        hw_indices=sel_idx[:, 0],
        cut_indices=sel_idx[:, 1],
        configs=tuple(config_space[h] for h in sel_idx[:, 0]),
        cuts=cuts_batch[sel_idx[:, 1]],
        n_feasible=int(rows.shape[0]),
        search_engine=search_engine,
    )


def _best_flow_result(
    out: np.ndarray,  # (H, C, 4) — real candidate rows only, padding sliced
    cuts_batch: np.ndarray,  # (C, E) — real cut rows, real edge columns
    g: GraphIR,
    config_space: Sequence[DLAConfig],
    constraints: Constraints,
    *,
    n_pruned: int,
    compile_seconds: float,
    sweep_seconds: float,
    candidates_per_second: float,
    search_engine: str = "",
    err_prefix: str = "",
    pareto: bool = False,
    poison: np.ndarray | None = None,
    quarantine: "QuarantineReport | None" = None,
) -> FlowResult:
    """Constraint filter + min-energy argmin over one graph's sweep output —
    the single best-point selection shared by run_flow and run_fleet (so
    feasibility/tie-break semantics can never drift between them).

    Tie-breaking is deterministic: among equal-energy feasible candidates
    the winner is the lexicographic minimum of (bandwidth, latency, area,
    h, c).  The selected *metrics* are therefore invariant to any
    permutation of the hardware axis, and the selected *config* is
    invariant up to fully-identical metric rows, where the lowest (h, c)
    index wins — so padding H to a device-count multiple or resharding the
    sweep can never flip the reported best point (asserted at 1/2/8 host
    devices in tests/test_multidevice.py).

    ``poison`` is the finite guard's (H, C) quarantine mask: poisoned
    cells are excluded from feasibility before any selection, so a NaN /
    Inf / negative / overflowed cost row can neither win the argmin nor
    enter the Pareto front.  A fully-poisoned candidate set raises
    :class:`PoisonedResultError` with the ``quarantine`` provenance.
    """
    limits = constraints.as_row()  # (4,)
    feasible = np.all(out <= limits[None, None, :], axis=-1)  # (H, C)
    if poison is not None:
        if poison.all():
            raise PoisonedResultError(
                f"{err_prefix}all {poison.size} candidates were poisoned "
                "(NaN/Inf/negative/overflowed cost rows) — nothing is left "
                "to select from",
                quarantined=(
                    quarantine.cells if quarantine is not None else ()
                ),
            )
        feasible &= ~poison
    n_feas = int(feasible.sum())
    if n_feas == 0:
        raise InfeasibleConstraintsError(
            f"{err_prefix}no candidate meets the constraints"
        )
    energy = np.where(feasible, out[:, :, 2], np.inf)
    ties = np.argwhere(energy == energy.min())  # (h, c) lexicographic order
    if len(ties) > 1:
        rows = out[ties[:, 0], ties[:, 1]]  # (k, 4)
        order = np.lexsort(
            (ties[:, 1], ties[:, 0], rows[:, 3], rows[:, 1], rows[:, 0])
        )
        ties = ties[order[:1]]
    h, c = ties[0]
    labels = fusion.cut_group_labels(g, cuts_batch[c])
    sizes = tuple(len(grp) for grp in fusion.groups_from_labels(labels))
    return FlowResult(
        best_hw=config_space[h],
        best_cuts=cuts_batch[c],
        best_metrics=_metrics_from_row(out[h, c]),
        group_sizes=sizes,
        n_candidates=out.shape[0] * out.shape[1],
        n_feasible=n_feas,
        n_pruned=n_pruned,
        compile_seconds=compile_seconds,
        sweep_seconds=sweep_seconds,
        candidates_per_second=candidates_per_second,
        search_engine=search_engine,
        pareto=(
            _pareto_front(out, feasible, cuts_batch, config_space,
                          search_engine)
            if pareto
            else None
        ),
        quarantine=quarantine,
    )


def groupings_batch(
    g: GraphIR,
    groupings: str | np.ndarray,
    *,
    sram_budget_words: float = float("inf"),
    with_provenance: bool = False,
) -> np.ndarray | tuple[np.ndarray, str]:
    """Resolve a groupings spec to a (C, E) boolean cut batch.

    ``"exhaustive"`` — all valid edge cuts (2^(L-1) on a chain);
    ``"pool"``       — the paper's pool-boundary policy + layer-by-layer;
    ``"search"``/``"dp"`` — the grouping search optimum (chain DP fast path,
    frontier DP — exact even on ResNet-scale DAGs — or beam fallback) +
    layer-by-layer + pool boundaries;
    or an explicit (C, E) bool array.  ``sram_budget_words`` is threaded
    into the search strategies so a budget-constrained flow searches under
    the same budget its prefilter enforces (a budget-blind optimum would
    just be pruned afterwards).  With ``with_provenance`` the batch comes
    back paired with the grouping provenance string (for "search"/"dp"
    the engine that produced the optimum, see
    :attr:`repro.core.fusion.DPResult.engine`).
    """

    def _ret(batch: np.ndarray, provenance: str):
        return (batch, provenance) if with_provenance else batch

    if not isinstance(groupings, str):
        return _ret(
            np.atleast_2d(np.asarray(groupings, dtype=bool)), "explicit"
        )
    if groupings == "exhaustive":
        try:
            return _ret(fusion.enumerate_valid_edge_cuts(g), "exhaustive")
        except ValueError as e:
            raise ValueError(
                f"{g.name}: {e}; pass groupings='search' for large graphs"
            ) from None
    if groupings == "pool":
        # np.unique-dedupe like the "search" path: on graphs where the pool
        # policy degenerates to layer-by-layer (e.g. every producer ends a
        # pooling stage) the duplicate row must not be scored twice.
        return _ret(
            np.unique(
                np.stack(
                    [g.pool_boundary_cuts(), fusion.layer_by_layer_cuts(g)]
                ),
                axis=0,
            ),
            "pool",
        )
    if groupings in ("dp", "search"):
        best = fusion.optimal_cuts(g, sram_budget_words=sram_budget_words)
        rows = [
            best.cuts,
            fusion.layer_by_layer_cuts(g),
            g.pool_boundary_cuts(),
        ]
        return _ret(np.unique(np.stack(rows), axis=0), best.engine)
    raise ValueError(groupings)


def run_flow(
    ir: NetworkIR | GraphIR,
    *,
    config_space: Sequence[DLAConfig] | None = None,
    constraints: Constraints = Constraints(),
    groupings: str | np.ndarray = "exhaustive",
    sram_budget_words: float = float("inf"),
    bucket: bool = True,
    pareto: bool = False,
) -> FlowResult:
    """Sweep (hw x grouping), filter by constraints, return min-energy point.

    ``groupings`` is resolved by :func:`groupings_batch`.  A finite
    ``sram_budget_words`` drops buffer-infeasible groupings *before* the
    sweep via the batched prefilter
    (:func:`repro.core.fusion.graph_feasible_mask_batch`), so the XLA
    program never evaluates candidates the budget would reject anyway.

    With ``bucket=True`` (the default) the ``(L, E, C)`` signature is
    rounded up to power-of-two shape buckets (floors ``NODE_BUCKET_FLOOR``
    etc.) and evaluated through the masked kernels — bit-identical metrics
    (padded rows are exactly inert), but graphs sharing a bucket share one
    compiled executable instead of each paying the XLA compile.  Bucketing
    the candidate axis re-pads the prefiltered batch with up to ~2x inert
    dummy rows (sliced off before the argmin) — microseconds of sweep work
    traded for skipping whole-seconds recompiles on every distinct
    surviving-candidate count.  ``bucket=False`` keeps the exact-shape,
    no-dummy signature (one compile per distinct graph — the benchmark
    baseline).

    The evaluator is AOT-compiled once per argument-shape signature;
    ``compile_seconds`` reports the XLA compilation paid by *this* call
    (0 on an executable-cache hit) and ``sweep_seconds`` /
    ``candidates_per_second`` the single timed execution.

    ``pareto=True`` additionally extracts the feasible sweep's
    (bandwidth, latency, energy, area) Pareto front into
    ``FlowResult.pareto`` (:class:`ParetoFront`).
    """
    if config_space is None:
        config_space = default_config_space()
    g = as_graph(ir)
    cuts_batch, provenance = groupings_batch(
        g, groupings, sram_budget_words=sram_budget_words,
        with_provenance=True,
    )

    n_pruned = 0
    if np.isfinite(sram_budget_words):
        max_int = fusion.graph_max_intermediate_batch(g, cuts_batch)
        keep = max_int <= sram_budget_words
        n_pruned = int(cuts_batch.shape[0] - keep.sum())
        if not keep.any():
            # Never return a silently-empty sweep: report the smallest
            # budget under which at least one offered grouping survives.
            raise InfeasibleBudgetError(
                f"{g.name}: no grouping fits the SRAM budget "
                f"({sram_budget_words:.0f} words; the cheapest offered "
                f"grouping needs {max_int.min():.0f})",
                min_feasible_budget_words=float(max_int.min()),
            )
        cuts_batch = cuts_batch[keep]
    C = cuts_batch.shape[0]

    hw_rows = np.stack([c.as_row() for c in config_space])
    area_consts = M.area_consts_of_space(config_space)

    if bucket:
        pg = pad_graph(
            g,
            n_nodes=bucket_size(g.n_nodes, NODE_BUCKET_FLOOR),
            n_edges=bucket_size(g.n_edges, EDGE_BUCKET_FLOOR),
        )
        args = (
            pg.feat,
            pg.esrc,
            pg.edst,
            pg.ewords,
            pg.src_mask,
            pg.sink_mask,
            pad_cuts_batch(
                cuts_batch, pg.n_edges_padded, bucket_size(C, CUT_BUCKET_FLOOR)
            ),
            hw_rows,
            area_consts,
            pg.node_mask,
            pg.edge_mask,
        )
    else:
        feat = g.node_features()
        esrc, edst, ewords = g.edge_arrays()
        args = (
            feat,
            esrc,
            edst,
            ewords,
            g.source_mask,
            g.sink_mask,
            cuts_batch,
            hw_rows,
            area_consts,
        )
    # f64-exactness guard: the bit-identity guarantee assumes every
    # feature / edge-word entry is an exactly-representable integer f64
    # (<= 2^53); a corrupted or overflowed table must fail loudly here,
    # not silently split ulps inside the sweep.
    M.assert_exact_f64(args[0], what=f"{g.name} feature table")
    M.assert_exact_f64(args[3], what=f"{g.name} edge words")
    exe, compile_seconds = _compiled_sweep(M._jit_batch_graph, args)
    # raw (H, C_b, 5) rows -> (H, C, 4) metrics, padded candidate rows
    # sliced off before feasibility/argmin
    raw, sweep_seconds = _run_sweep(exe, args)
    out = M.compose_metrics(raw, hw_rows)[:, :C]
    # Finite guard: quarantine poisoned raw cells before any selection.
    poison = M.poison_mask(raw)[:, :C]
    quarantine = None
    if poison.any():
        quarantine = QuarantineReport(
            cells=_quarantine_cells(raw[:, :C], poison, graph=0)
        )
    else:
        poison = None
    n_cand = out.shape[0] * C
    return _best_flow_result(
        out, cuts_batch, g, config_space, constraints,
        n_pruned=n_pruned,
        compile_seconds=compile_seconds,
        sweep_seconds=sweep_seconds,
        candidates_per_second=n_cand / max(sweep_seconds, 1e-9),
        search_engine=provenance,
        pareto=pareto,
        poison=poison,
        quarantine=quarantine,
    )


@dataclasses.dataclass(frozen=True)
class FleetResult:
    """One multi-graph sweep: per-graph best points + shared-compile split."""

    results: tuple[FlowResult, ...]  # one FlowResult per input graph
    n_graphs: int
    n_candidates: int  # real (graph, hw, cut) triples across the fleet
    compile_seconds: float  # ONE compile amortised across the whole fleet
    sweep_seconds: float  # the single timed (G, H, C) execution
    candidates_per_second: float
    # Device layout the sweep ran on: 1 for the single-device program,
    # else the size of the 1-D `hardware` mesh the H axis was sharded over.
    device_count: int = 1
    # Fleet-wide finite-guard report (None when every raw cell was clean).
    quarantine: "QuarantineReport | None" = None
    # Salvage/resume accounting: chunks actually computed this call vs
    # restored from a sweep checkpoint (1/0 for an unchunked sweep), chunk
    # indices the straggler detector flagged, and whether a sick mesh was
    # degraded to the single-device program mid-call.
    chunks_computed: int = 1
    chunks_restored: int = 0
    straggler_chunks: tuple[int, ...] = ()
    mesh_degraded: bool = False

    def describe(self) -> str:
        """One-line summary of the fleet sweep (incl. mesh, if sharded)."""
        mesh = (
            f", {self.device_count}-device hardware mesh"
            if self.device_count > 1
            else ""
        )
        if self.mesh_degraded:
            mesh = ", mesh degraded to single-device"
        salvage = (
            f", {self.chunks_restored} chunks restored"
            if self.chunks_restored
            else ""
        )
        lines = [
            f"fleet of {self.n_graphs}: {self.n_candidates} candidates in "
            f"{self.sweep_seconds*1e3:.2f} ms "
            f"({self.candidates_per_second:,.0f} cand/s, one compile "
            f"{self.compile_seconds*1e3:.0f} ms{mesh}{salvage})"
        ]
        lines += [f"  {r.describe()}" for r in self.results]
        return "\n".join(lines)


def run_fleet(
    irs: Sequence[NetworkIR | GraphIR],
    *,
    config_space: Sequence[DLAConfig] | None = None,
    constraints: Constraints = Constraints(),
    groupings: str | np.ndarray | Sequence[np.ndarray] = "search",
    sram_budget_words: float = float("inf"),
    devices=None,
    pareto: bool = False,
    hw_chunk: int | None = None,
    abort_check=None,
    retry_policy: RetryPolicy | None = None,
    checkpoint_dir=None,
    hooks=None,
) -> FleetResult:
    """Sweep many graphs' (hw x grouping) cross-products in ONE XLA program.

    Every graph is zero-padded to the fleet-wide ``(L, E, C)`` bucket
    (power-of-two, same floors as :func:`run_flow`), stacked along a new
    leading axis, and evaluated by a single vmapped executable
    (:func:`repro.core.metrics.evaluate_fleet_graph`) — the whole fleet
    pays at most one XLA compile (0 on a bucket-cache hit), which is the
    serving-system move the per-model cold path cannot make.  Per-graph
    metrics are bit-identical to :func:`run_flow` (padded rows are exactly
    inert and sliced off before feasibility/argmin; asserted in tests).

    ``groupings`` / ``sram_budget_words`` / ``constraints`` apply to every
    graph — except that ``groupings`` may also be a *sequence* of explicit
    per-graph cut batches (one (C_i, E_i) bool array per input graph), the
    form the planning service uses to sweep a micro-batch of requests
    whose deadline ladders resolved to different engines.  The SRAM
    prefilter runs per graph on the padded cut rows
    (:func:`repro.core.fusion.padded_feasible_mask_batch`).  Returns a
    :class:`FleetResult` whose ``results[i]`` is graph ``i``'s
    :class:`FlowResult`; the shared compile is reported fleet-level, so
    per-graph ``compile_seconds`` is 0, and per-graph ``sweep_seconds`` /
    ``candidates_per_second`` describe the one shared execution (every
    member reports the fleet-wide throughput, not its own slice of it).

    ``devices`` shards the sweep's hardware axis over a 1-D ``hardware``
    mesh (:func:`repro.parallel.sharding.hardware_mesh`): ``None`` keeps
    the single-device program; an int takes the first N visible devices;
    a device sequence is used as given.  H is padded to a device-count
    multiple with copies of config 0 — inert rows sliced off before
    metrics composition, the PR 4 padding idiom on the hardware axis — and
    each device evaluates its H-shard locally; the (G, H, C, 5) raw plane
    comes back in one cross-device gather and the per-graph
    argmin/Pareto run on the host exactly as in the single-device path, so
    sharded results are **bit-identical** at any device count (asserted at
    1/2/8 host devices in tests/test_multidevice.py).  The executable
    cache keys on the mesh fingerprint, so per-layout programs never
    collide (``sweep_cache_stats()["entries"]``).

    ``pareto=True`` extracts each workload's feasible-sweep Pareto front
    over (bandwidth, latency, energy, area) into ``results[i].pareto`` —
    with a :func:`repro.core.arch.config_space_grid` design space this is
    the LoopTree-style explorer output: thousands of
    (architecture x fusion plan) points scored per workload, reduced to
    the non-dominated set.

    Example — two workloads, default space, per-workload fronts::

        >>> from repro.core import flow
        >>> from repro.core.ir import residual_block_ir, resnet18_ir
        >>> fl = flow.run_fleet([residual_block_ir(), resnet18_ir()],
        ...                     groupings="search", pareto=True)
        >>> len(fl.results), fl.device_count
        (2, 1)
        >>> r = fl.results[1]                    # resnet18's FlowResult
        >>> r.search_engine, r.best_cuts.dtype.name
        ('frontier_dp', 'bool')
        >>> r.pareto.metrics.shape[1]            # (bw, latency, energy, area)
        4

    ``hw_chunk`` splits the sweep into resumable slices of the hardware
    axis: the fleet program runs once per ≤``hw_chunk``-row slice of the
    config space and the raw (G, h, C, 5) planes are reassembled before
    metrics composition.  Every raw row is an exact per-candidate f64
    quantity (energy is composed *outside* XLA), so the chunked sweep is
    **bit-identical** to the unchunked one — chunking only creates
    preemption points.  ``abort_check`` (a zero-arg callable) is invoked
    before each chunk; raising from it abandons the remaining chunks,
    which is how the planning service implements cooperative cancellation
    and deadline enforcement at sweep-chunk granularity without ever
    killing a kernel mid-flight.  ``hw_chunk`` cannot be combined with
    ``devices`` (the sharded program already splits H across the mesh).

    Fault tolerance (all off by default):

    * ``retry_policy`` (:class:`repro.core.errors.RetryPolicy`) retries
      each chunk's compile+execute on non-evaluator failures with
      exponential backoff; exhaustion raises a typed
      :class:`~repro.core.errors.TransientFailure`.  On the sharded
      (``devices=``) path, exhaustion instead *degrades*: the sweep falls
      back down :func:`repro.runtime.elastic.sweep_degradation_ladder`
      to the single-device program — bit-identical results, only slower
      (``FleetResult.mesh_degraded`` records it).
    * ``checkpoint_dir`` (requires ``hw_chunk``) persists every completed
      chunk's raw plane through the journal's bit-exact codecs
      (:class:`repro.checkpoint.SweepCheckpoint`); a killed sweep re-run
      with the same arguments restores completed chunks and recomputes
      only the missing ones (``chunks_restored``/``chunks_computed``) —
      the resumed :class:`FleetResult` is bit-identical to an unkilled
      run.  The checkpoint is keyed by a fingerprint of the full argument
      set, so a different sweep can never splice in stale planes.
    * Per-chunk wall times feed a running-median straggler detector
      (:class:`repro.runtime.fault_tolerance.StragglerDetector`); flagged
      chunk indices are reported in ``FleetResult.straggler_chunks``.
    * ``hooks`` is a duck-typed fault seam (``before_chunk_compute(i,
      device_count=...)`` may raise to simulate a shard/compile failure;
      ``poison_plane(plane, h0)`` may corrupt a raw plane) used by
      :class:`repro.testing.faults.FaultInjector`; every raw plane then
      passes the finite guard, so injected NaN/Inf/negative/overflow
      cells are quarantined with (g, h, c) provenance
      (``FleetResult.quarantine``) and can never win the argmin or enter
      a Pareto front.

    Example — per-graph explicit cut batches (the service/bench form) and
    a sharded hardware axis::

        >>> import numpy as np
        >>> gs = [residual_block_ir(), resnet18_ir()]
        >>> batches = [np.stack([np.ones(g.n_edges, bool),    # layer-by-layer
        ...                      np.zeros(g.n_edges, bool)])  # fully fused
        ...            for g in gs]
        >>> fl = flow.run_fleet(gs, groupings=batches, devices=1)
        >>> [len(r.group_sizes) for r in fl.results]  # groups of best cuts
        [1, 1]
    """
    if not irs:
        raise ValueError("empty fleet")
    if hw_chunk is not None:
        if devices is not None:
            raise ValueError(
                "hw_chunk cannot be combined with devices: the sharded "
                "program already splits the hardware axis across the mesh"
            )
        if hw_chunk <= 0:
            raise ValueError(f"hw_chunk must be positive, got {hw_chunk}")
    if checkpoint_dir is not None and hw_chunk is None:
        raise ValueError(
            "checkpoint_dir requires hw_chunk: completed hardware-axis "
            "chunks are the checkpoint grain"
        )
    if config_space is None:
        config_space = default_config_space()
    graphs = [as_graph(ir) for ir in irs]

    # ``groupings`` may be one spec shared by the whole fleet, or a
    # per-graph sequence of explicit (C_i, E_i) cut batches (the planning
    # service resolves each request's grouping through its deadline ladder
    # and sweeps the mixed batch as one fleet program).
    if isinstance(groupings, (list, tuple)):
        if len(groupings) != len(graphs):
            raise ValueError(
                f"{len(groupings)} grouping specs for {len(graphs)} graphs"
            )
        specs = list(groupings)
    else:
        specs = [groupings] * len(graphs)

    # Per-graph grouping resolution + SRAM prefilter (padded-E cut rows).
    edge_bucket = bucket_size(
        max(g.n_edges for g in graphs), EDGE_BUCKET_FLOOR
    )
    node_bucket = bucket_size(
        max(g.n_nodes for g in graphs), NODE_BUCKET_FLOOR
    )
    padded = [pad_graph(g, n_nodes=node_bucket, n_edges=edge_bucket)
              for g in graphs]
    cuts: list[np.ndarray] = []
    pruned: list[int] = []
    provenances: list[str] = []
    for g, pg, spec in zip(graphs, padded, specs):
        cb, provenance = groupings_batch(
            g, spec, sram_budget_words=sram_budget_words,
            with_provenance=True,
        )
        cb = pad_cuts_batch(cb, edge_bucket)
        provenances.append(provenance)
        n_pruned = 0
        if np.isfinite(sram_budget_words):
            max_int = fusion.padded_max_intermediate_batch(pg, cb)
            keep = max_int <= sram_budget_words
            n_pruned = int(cb.shape[0] - keep.sum())
            if not keep.any():
                raise InfeasibleBudgetError(
                    f"{g.name}: no grouping fits the SRAM budget "
                    f"({sram_budget_words:.0f} words; the cheapest offered "
                    f"grouping needs {max_int.min():.0f})",
                    min_feasible_budget_words=float(max_int.min()),
                )
            cb = cb[keep]
        cuts.append(cb)
        pruned.append(n_pruned)
    counts = [cb.shape[0] for cb in cuts]
    cut_bucket = bucket_size(max(counts), CUT_BUCKET_FLOOR)
    cuts = [pad_cuts_batch(cb, edge_bucket, cut_bucket) for cb in cuts]

    hw_rows = np.stack([c.as_row() for c in config_space])
    area_consts = M.area_consts_of_space(config_space)
    H = hw_rows.shape[0]

    # Device layout: single-device vmapped program, or the same kernel
    # shard_mapped over a 1-D `hardware` mesh with H padded to a
    # device-count multiple (padded rows are copies of config 0 — fully
    # valid arithmetic, sliced off below before metrics composition).
    mesh_key = _SINGLE_MESH_KEY
    hw_swept = hw_rows
    if devices is None:
        kernel = M._jit_fleet_graph
    else:
        mesh = hardware_mesh(devices)
        kernel = M.sharded_fleet_kernel(mesh)
        mesh_key = mesh_fingerprint(mesh)
        D = int(mesh.devices.size)
        H_padded = -(-H // D) * D
        if H_padded > H:
            hw_swept = np.concatenate(
                [hw_rows, np.repeat(hw_rows[:1], H_padded - H, axis=0)]
            )

    args = (
        np.stack([pg.feat for pg in padded]),
        np.stack([pg.esrc for pg in padded]),
        np.stack([pg.edst for pg in padded]),
        np.stack([pg.ewords for pg in padded]),
        np.stack([pg.src_mask for pg in padded]),
        np.stack([pg.sink_mask for pg in padded]),
        np.stack(cuts),
        hw_swept,
        area_consts,
        np.stack([pg.node_mask for pg in padded]),
        np.stack([pg.edge_mask for pg in padded]),
    )
    # f64-exactness guard on the giant-config feature tables (llama4 /
    # arctic edge words reach ~1e10 — far below 2^53, but a corrupted or
    # overflowed table must fail loudly before the sweep, not split ulps
    # silently inside it).
    M.assert_exact_f64(args[0], what="fleet feature table")
    M.assert_exact_f64(args[3], what="fleet edge words")
    if abort_check is not None:
        abort_check()

    hook_before = (
        getattr(hooks, "before_chunk_compute", None)
        if hooks is not None else None
    )
    hook_poison = (
        getattr(hooks, "poison_plane", None) if hooks is not None else None
    )
    sweep_device_count = 1 if devices is None else int(mesh.devices.size)

    def _compute(chunk_index, c_args, c_kernel, c_mesh_key, h0, d_count):
        """One chunk's compile+execute, under the retry policy + hooks."""

        def attempt():
            if hook_before is not None:
                hook_before(chunk_index, device_count=d_count)
            exe, dt_c = _compiled_sweep(c_kernel, c_args, mesh_key=c_mesh_key)
            plane, dt_s = _run_sweep(exe, c_args)
            return plane, dt_c, dt_s

        if retry_policy is None:
            plane, dt_c, dt_s = attempt()
        else:
            plane, dt_c, dt_s = retry_policy.call(
                attempt, describe=f"hw chunk {chunk_index}"
            )
        if hook_poison is not None:
            plane = hook_poison(plane, h0)
        return plane, dt_c, dt_s

    mesh_degraded = False
    chunks_restored = 0
    straggler_chunks: tuple[int, ...] = ()
    if hw_chunk is None:
        chunks_computed = 1
        try:
            raw, compile_seconds, sweep_seconds = _compute(
                0, args, kernel, mesh_key, 0, sweep_device_count
            )
        except TransientFailure:
            from ..runtime.elastic import sweep_degradation_ladder

            ladder = sweep_degradation_ladder(devices)[1:]
            if not ladder:
                raise
            # The mesh is sick (compile/execute kept failing through the
            # retry budget): degrade to the ladder's single-device rung.
            # The sharded kernel is row-parallel with no cross-row
            # reduction, so the salvaged result is bit-identical to the
            # mesh sweep — the fallback trades throughput, never answers.
            mesh_degraded = True
            kernel, mesh_key = M._jit_fleet_graph, _SINGLE_MESH_KEY
            args = args[:7] + (hw_rows,) + args[8:]
            raw, compile_seconds, sweep_seconds = _compute(
                0, args, kernel, mesh_key, 0, 1
            )
    else:
        # Resumable chunked sweep: one program per ≤hw_chunk-row slice of
        # the config space, abort_check between slices.  Raw rows are
        # per-candidate-exact, so the reassembled plane is bit-identical
        # to the single-program sweep.  With ``checkpoint_dir`` every
        # completed plane is durable before the loop advances, so a kill
        # at ANY boundary resumes with exactly-once recomputation.
        from ..runtime.fault_tolerance import StragglerDetector

        restored: dict[int, np.ndarray] = {}
        ckpt = None
        if checkpoint_dir is not None:
            from ..checkpoint import SweepCheckpoint, sweep_fingerprint

            ckpt = SweepCheckpoint(checkpoint_dir)
            restored = ckpt.load(sweep_fingerprint(args, hw_chunk))
        detector = StragglerDetector(min_deadline_s=0.0)
        compile_seconds = sweep_seconds = 0.0
        chunks_computed = 0
        stragglers: list[int] = []
        planes = []
        for ci, h0 in enumerate(range(0, H, hw_chunk)):
            if abort_check is not None and h0:
                abort_check()
            plane = restored.get(h0)
            if plane is not None:
                planes.append(plane)
                chunks_restored += 1
                continue
            chunk_args = (
                args[:7] + (hw_rows[h0:h0 + hw_chunk],) + args[8:]
            )
            t_chunk = time.perf_counter()
            plane, dt_c, dt_s = _compute(
                ci, chunk_args, kernel, mesh_key, h0, sweep_device_count
            )
            # Straggler detection on wall time net of compile (a cold
            # cache is not a sick worker); the detector needs 5 samples
            # before it flags, so early chunks only seed the median.
            dt_wall = time.perf_counter() - t_chunk - dt_c
            if detector.is_straggler(dt_wall):
                stragglers.append(ci)
            detector.observe(dt_wall)
            if ckpt is not None:
                ckpt.append_chunk(h0, plane)
            planes.append(plane)
            chunks_computed += 1
            compile_seconds += dt_c
            sweep_seconds += dt_s
        straggler_chunks = tuple(stragglers)
        raw = np.concatenate(planes, axis=1)
    out = M.compose_metrics(raw[:, :H], hw_rows)  # (G, H, C_b, 4)
    # Finite guard over the whole fleet's raw plane: poisoned cells are
    # quarantined per graph before any argmin/Pareto selection.
    poison_all = M.poison_mask(raw[:, :H])  # (G, H, C_b)
    any_poison = bool(poison_all.any())
    fleet_cells: list[QuarantinedCell] = []
    n_cand = H * sum(counts)
    fleet_cps = n_cand / max(sweep_seconds, 1e-9)
    results = []
    for gi, g in enumerate(graphs):
        C = counts[gi]
        g_poison = None
        g_quar = None
        if any_poison:
            pm = poison_all[gi, :, :C]
            if pm.any():
                cells = _quarantine_cells(raw[gi, :H, :C], pm, graph=gi)
                g_quar = QuarantineReport(cells=cells)
                fleet_cells.extend(cells)
                g_poison = pm
        results.append(
            _best_flow_result(
                out[gi, :, :C],  # padded candidate rows sliced off
                cuts[gi][:C, : g.n_edges],
                g, config_space, constraints,
                n_pruned=pruned[gi],
                compile_seconds=0.0,  # the one fleet compile, see FleetResult
                sweep_seconds=sweep_seconds,
                candidates_per_second=fleet_cps,  # the shared execution rate
                search_engine=provenances[gi],
                err_prefix=f"{g.name}: ",
                pareto=pareto,
                poison=g_poison,
                quarantine=g_quar,
            )
        )
    return FleetResult(
        results=tuple(results),
        n_graphs=len(graphs),
        n_candidates=n_cand,
        compile_seconds=compile_seconds,
        sweep_seconds=sweep_seconds,
        candidates_per_second=fleet_cps,
        device_count=1 if mesh_degraded else sweep_device_count,
        quarantine=(
            QuarantineReport(cells=tuple(fleet_cells))
            if fleet_cells
            else None
        ),
        chunks_computed=chunks_computed,
        chunks_restored=chunks_restored,
        straggler_chunks=straggler_chunks,
        mesh_degraded=mesh_degraded,
    )


@dataclasses.dataclass(frozen=True)
class FusionComparison:
    """Layer-by-layer vs fused metrics for one (network, hw) — the paper's
    headline Sec. III numbers."""

    lbl: M.Metrics
    fused: M.Metrics
    bw_reduction: float
    latency_reduction: float
    energy_reduction: float

    def describe(self) -> str:
        """Three-line lbl -> fused table with percentage reductions."""
        return (
            f"BW  {self.lbl.bandwidth_words/1e6:8.2f}M -> {self.fused.bandwidth_words/1e6:8.2f}M  (-{self.bw_reduction*100:5.1f}%)\n"
            f"lat {self.lbl.latency_cycles/1e6:8.2f}M -> {self.fused.latency_cycles/1e6:8.2f}M  (-{self.latency_reduction*100:5.1f}%)\n"
            f"E   {self.lbl.energy_nj/1e6:8.2f}mJ-> {self.fused.energy_nj/1e6:8.2f}mJ (-{self.energy_reduction*100:5.1f}%)"
        )


def compare_fusion(
    ir: NetworkIR | GraphIR,
    hw: DLAConfig,
    fused_cuts: np.ndarray | None = None,
) -> FusionComparison:
    """Evaluate the paper's fused-vs-layer-by-layer comparison on ``ir``."""
    g = as_graph(ir)
    if fused_cuts is None:
        fused_cuts = g.pool_boundary_cuts()
    lbl_cuts = fusion.layer_by_layer_cuts(g)
    lbl = M.evaluate_ref(g, lbl_cuts, hw)
    fus = M.evaluate_ref(g, fused_cuts, hw)
    return FusionComparison(
        lbl=lbl,
        fused=fus,
        bw_reduction=1.0 - fus.bandwidth_words / lbl.bandwidth_words,
        latency_reduction=1.0 - fus.latency_cycles / lbl.latency_cycles,
        energy_reduction=1.0 - fus.energy_nj / lbl.energy_nj,
    )
