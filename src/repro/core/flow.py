"""The paper's optimisation flow (Sec. II-C), generalised to graph IRs.

For each (hardware configuration x fusion grouping) candidate, estimate the
four metrics, reject candidates violating the user constraints, and return
the feasible candidate with minimum energy.  The cross-product is evaluated
as a single jitted/vmapped XLA program
(:func:`repro.core.metrics.evaluate_batch_graph`), which is the JAX-native
realisation of the paper's exhaustive sweep — the benchmark reports
candidates/second.  Groupings are boolean cut vectors over the graph's
edges; chains (``NetworkIR``) are embedded losslessly via
:func:`repro.core.ir.as_graph`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from . import fusion
from . import metrics as M
from .arch import Constraints, DLAConfig, default_config_space
from .ir import GraphIR, NetworkIR, as_graph


@dataclasses.dataclass(frozen=True)
class FlowResult:
    best_hw: DLAConfig
    best_cuts: np.ndarray
    best_metrics: M.Metrics
    group_sizes: tuple[int, ...]
    n_candidates: int
    n_feasible: int
    n_pruned: int  # groupings dropped by the SRAM prefilter before the sweep
    compile_seconds: float  # XLA compile paid by this call (0 on cache hit)
    sweep_seconds: float  # the single timed execution
    candidates_per_second: float

    def describe(self) -> str:
        return (
            f"best={self.best_hw.describe()} groups={list(self.group_sizes)} "
            f"BW={self.best_metrics.bandwidth_words/1e6:.2f}M words "
            f"lat={self.best_metrics.latency_cycles/1e6:.2f}M cyc "
            f"E={self.best_metrics.energy_nj/1e6:.2f} mJ "
            f"A={self.best_metrics.area_um2/1e6:.2f} mm^2 "
            f"({self.n_feasible}/{self.n_candidates} feasible, "
            f"{self.n_pruned} pruned, "
            f"{self.candidates_per_second:,.0f} cand/s, "
            f"compile {self.compile_seconds*1e3:.0f} ms)"
        )


# AOT-compiled evaluator executables keyed by argument shapes, so a
# run_flow call executes the sweep exactly once: the first call with a new
# shape signature pays (and reports) the XLA compile, repeats reuse the
# executable and report compile_seconds == 0.
_COMPILED_SWEEPS: dict[tuple, object] = {}


def _compiled_sweep(args) -> tuple[object, float]:
    """(executable, compile_seconds_this_call) for evaluate_batch_graph."""
    key = tuple((a.shape, str(a.dtype)) for a in args)
    exe = _COMPILED_SWEEPS.get(key)
    if exe is not None:
        return exe, 0.0
    t0 = time.perf_counter()
    exe = M.evaluate_batch_graph.lower(*args).compile()
    dt = time.perf_counter() - t0
    if len(_COMPILED_SWEEPS) >= 64:
        _COMPILED_SWEEPS.clear()
    _COMPILED_SWEEPS[key] = exe
    return exe, dt


def _metrics_from_row(row: np.ndarray) -> M.Metrics:
    return M.Metrics(
        bandwidth_words=float(row[0]),
        latency_cycles=float(row[1]),
        energy_nj=float(row[2]),
        area_um2=float(row[3]),
    )


def groupings_batch(
    g: GraphIR,
    groupings: str | np.ndarray,
    *,
    sram_budget_words: float = float("inf"),
) -> np.ndarray:
    """Resolve a groupings spec to a (C, E) boolean cut batch.

    ``"exhaustive"`` — all valid edge cuts (2^(L-1) on a chain);
    ``"pool"``       — the paper's pool-boundary policy + layer-by-layer;
    ``"search"``/``"dp"`` — the grouping search optimum (chain DP fast path,
    exhaustive or beam on DAGs) + layer-by-layer + pool boundaries;
    or an explicit (C, E) bool array.  ``sram_budget_words`` is threaded
    into the search strategies so a budget-constrained flow searches under
    the same budget its prefilter enforces (a budget-blind optimum would
    just be pruned afterwards).
    """
    if not isinstance(groupings, str):
        return np.atleast_2d(np.asarray(groupings, dtype=bool))
    if groupings == "exhaustive":
        try:
            return fusion.enumerate_valid_edge_cuts(g)
        except ValueError as e:
            raise ValueError(
                f"{g.name}: {e}; pass groupings='search' for large graphs"
            ) from None
    if groupings == "pool":
        return np.stack([g.pool_boundary_cuts(), fusion.layer_by_layer_cuts(g)])
    if groupings in ("dp", "search"):
        rows = [
            fusion.optimal_cuts(g, sram_budget_words=sram_budget_words).cuts,
            fusion.layer_by_layer_cuts(g),
            g.pool_boundary_cuts(),
        ]
        return np.unique(np.stack(rows), axis=0)
    raise ValueError(groupings)


def run_flow(
    ir: NetworkIR | GraphIR,
    *,
    config_space: Sequence[DLAConfig] | None = None,
    constraints: Constraints = Constraints(),
    groupings: str | np.ndarray = "exhaustive",
    sram_budget_words: float = float("inf"),
) -> FlowResult:
    """Sweep (hw x grouping), filter by constraints, return min-energy point.

    ``groupings`` is resolved by :func:`groupings_batch`.  A finite
    ``sram_budget_words`` drops buffer-infeasible groupings *before* the
    sweep via the batched prefilter
    (:func:`repro.core.fusion.graph_feasible_mask_batch`), so the XLA
    program never evaluates candidates the budget would reject anyway.
    The evaluator is AOT-compiled once per argument-shape signature;
    ``compile_seconds`` reports the XLA compilation paid by *this* call
    (0 on an executable-cache hit) and ``sweep_seconds`` /
    ``candidates_per_second`` the single timed execution.
    """
    if config_space is None:
        config_space = default_config_space()
    g = as_graph(ir)
    feat = g.node_features()
    esrc, edst, ewords = g.edge_arrays()
    cuts_batch = groupings_batch(
        g, groupings, sram_budget_words=sram_budget_words
    )

    n_pruned = 0
    if np.isfinite(sram_budget_words):
        keep = fusion.graph_feasible_mask_batch(g, cuts_batch, sram_budget_words)
        n_pruned = int(cuts_batch.shape[0] - keep.sum())
        if not keep.any():
            raise ValueError("no grouping fits the SRAM budget")
        cuts_batch = cuts_batch[keep]

    hw_rows = np.stack([c.as_row() for c in config_space])
    area_consts = M.area_consts_of(config_space[0])

    args = (
        jnp.asarray(feat),
        jnp.asarray(esrc),
        jnp.asarray(edst),
        jnp.asarray(ewords),
        jnp.asarray(g.source_mask),
        jnp.asarray(g.sink_mask),
        jnp.asarray(cuts_batch),
        jnp.asarray(hw_rows),
        jnp.asarray(area_consts),
    )
    exe, compile_seconds = _compiled_sweep(args)
    t1 = time.perf_counter()
    out = np.asarray(exe(*args))  # (H, C, 4)
    sweep_seconds = time.perf_counter() - t1

    limits = constraints.as_row()  # (4,)
    feasible = np.all(out <= limits[None, None, :], axis=-1)  # (H, C)
    n_cand = out.shape[0] * out.shape[1]
    n_feas = int(feasible.sum())
    if n_feas == 0:
        raise ValueError("no candidate meets the constraints")
    energy = np.where(feasible, out[:, :, 2], np.inf)
    h, c = np.unravel_index(np.argmin(energy), energy.shape)
    labels = fusion.cut_group_labels(g, cuts_batch[c])
    sizes = tuple(len(grp) for grp in fusion.groups_from_labels(labels))
    return FlowResult(
        best_hw=config_space[h],
        best_cuts=cuts_batch[c],
        best_metrics=_metrics_from_row(out[h, c]),
        group_sizes=sizes,
        n_candidates=n_cand,
        n_feasible=n_feas,
        n_pruned=n_pruned,
        compile_seconds=compile_seconds,
        sweep_seconds=sweep_seconds,
        candidates_per_second=n_cand / max(sweep_seconds, 1e-9),
    )


@dataclasses.dataclass(frozen=True)
class FusionComparison:
    """Layer-by-layer vs fused metrics for one (network, hw) — the paper's
    headline Sec. III numbers."""

    lbl: M.Metrics
    fused: M.Metrics
    bw_reduction: float
    latency_reduction: float
    energy_reduction: float

    def describe(self) -> str:
        return (
            f"BW  {self.lbl.bandwidth_words/1e6:8.2f}M -> {self.fused.bandwidth_words/1e6:8.2f}M  (-{self.bw_reduction*100:5.1f}%)\n"
            f"lat {self.lbl.latency_cycles/1e6:8.2f}M -> {self.fused.latency_cycles/1e6:8.2f}M  (-{self.latency_reduction*100:5.1f}%)\n"
            f"E   {self.lbl.energy_nj/1e6:8.2f}mJ-> {self.fused.energy_nj/1e6:8.2f}mJ (-{self.energy_reduction*100:5.1f}%)"
        )


def compare_fusion(
    ir: NetworkIR | GraphIR,
    hw: DLAConfig,
    fused_cuts: np.ndarray | None = None,
) -> FusionComparison:
    """Evaluate the paper's fused-vs-layer-by-layer comparison on ``ir``."""
    g = as_graph(ir)
    if fused_cuts is None:
        fused_cuts = g.pool_boundary_cuts()
    lbl_cuts = fusion.layer_by_layer_cuts(g)
    lbl = M.evaluate_ref(g, lbl_cuts, hw)
    fus = M.evaluate_ref(g, fused_cuts, hw)
    return FusionComparison(
        lbl=lbl,
        fused=fus,
        bw_reduction=1.0 - fus.bandwidth_words / lbl.bandwidth_words,
        latency_reduction=1.0 - fus.latency_cycles / lbl.latency_cycles,
        energy_reduction=1.0 - fus.energy_nj / lbl.energy_nj,
    )
