"""Accelerator architecture models for the pre-RTL evaluator.

The paper's DLA (Fig. 1) is parameterised by the PE-array factors
``(F1, F2, F3, F4)`` — F1 output channels x F4 input channels of PE blocks,
each block an F2 x F3 (Hsiao et al. [2]) or F2 x 3 (VWA [3]) array of PEs:

* ``hsiao`` [2]: each PE holds 9 multipliers + an adder tree, i.e. one PE
  retires a full 3x3 kernel window per cycle.
* ``vwa``   [3]: each PE holds 1 multiplier + adder; the block's 3 columns
  stream kernel columns with a 1-D broadcast dataflow.

A third entry, ``tpu_v5e``, models the TPU target of this framework so the
same evaluator produces the roofline tables (197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI, 128 MiB VMEM).

Energy constants follow Sec. III: ``E_DRAM = 1 nJ`` per word access,
``E_SRAM = 0.1 nJ`` per word access, ``E_PB = 0.01 nJ`` per PE-block cycle.
(The per-PE-block-cycle reading of E_PB is the calibration under which the
paper's own 65 mJ constraint and 49.2 % energy-reduction figure are mutually
consistent — see benchmarks/run.py::table1 for the arithmetic.)
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Sequence

import numpy as np

from .errors import ConfigValidationError

# ---------------------------------------------------------------------------
# DLA configurations (the paper's ASIC models)
# ---------------------------------------------------------------------------

ARCH_STYLES = ("hsiao", "vwa")


@dataclasses.dataclass(frozen=True)
class DLAConfig:
    """One point in the paper's hardware configuration space."""

    style: str  # "hsiao" | "vwa"
    f1: int  # output-channel parallel PE blocks
    f2: int  # PE rows per block
    f3: int  # PE cols per block (forced to 3 for vwa)
    f4: int  # input-channel parallel PE blocks

    # E_PE accounting granularity.  "pe_cycle": every PE burns E_PB each busy
    # cycle (under-utilised lanes still clock => ceil-tiling waste costs
    # energy; this is the calibration under which the paper's (4,4,4,4)
    # optimum is reproduced).  "block_cycle": one count per PE *block* cycle.
    pe_energy: str = "pe_cycle"

    # --- micro-architecture constants (documented modeling choices) --------
    dram_words_per_cycle: int = 4  # DRAM bus words/cycle (calibrated, Sec III)
    pipeline_latency: int = 16  # t_PL fill cycles per layer
    mults_per_pe: int = dataclasses.field(init=False, default=0)

    # --- energy (nJ per access / per PE-block-cycle), Sec. III -------------
    e_dram_nj: float = 1.0
    e_sram_nj: float = 0.1
    e_pb_nj: float = 0.01

    # --- area (TSMC 40nm, um^2) ---------------------------------------------
    area_per_mult_um2: float = 600.0  # 8-bit multiplier + share of adder tree
    area_per_pe_overhead_um2: float = 150.0  # regs + control per PE
    area_per_sram_byte_um2: float = 2.5
    area_controller_um2: float = 150_000.0

    def __post_init__(self):
        if self.style not in ARCH_STYLES:
            raise ConfigValidationError(f"unknown style {self.style!r}")
        if self.style == "vwa" and self.f3 != 3:
            raise ConfigValidationError("VWA PE blocks are F2 x 3 (f3 must be 3)")
        if self.pe_energy not in ("pe_cycle", "block_cycle"):
            raise ConfigValidationError(f"unknown pe_energy {self.pe_energy!r}")
        for f in (self.f1, self.f2, self.f3, self.f4):
            if f < 1:
                raise ConfigValidationError("PE factors must be >= 1")
        object.__setattr__(self, "mults_per_pe", 9 if self.style == "hsiao" else 1)

    # ---- compute geometry ---------------------------------------------------
    @property
    def pes_per_block(self) -> int:
        """PEs in one block: the F2 x F3 tile."""
        return self.f2 * self.f3

    @property
    def n_blocks(self) -> int:
        """Block count: F1 output-channel x F4 input-channel tiles."""
        return self.f1 * self.f4

    @property
    def n_pes(self) -> int:
        """Total processing elements across all blocks."""
        return self.n_blocks * self.pes_per_block

    @property
    def macs_per_cycle(self) -> int:
        """Peak MAC throughput (hsiao PEs carry 9 multipliers, vwa 1)."""
        return self.n_pes * self.mults_per_pe

    @property
    def pe_units(self) -> int:
        """E_PE multiplier per busy cycle (see ``pe_energy``)."""
        return self.n_pes if self.pe_energy == "pe_cycle" else self.n_blocks

    # ---- Eq. (2) latency terms ---------------------------------------------
    def pe_busy_cycles(self, *, macs: float, n_in: float, n_out: float,
                       kh: float, kw: float, pixels_out: float) -> float:
        """t_PB with ceil-tiling over the (F1, F4, spatial, kernel) factors.

        hsiao: a PE retires min(kh*kw, 9) MACs/cycle; the F2 x F3 block tiles
        output pixels.  vwa: a PE retires 1 MAC/cycle; the block's 3 columns
        tile the kernel width and F2 rows tile output rows.
        """
        if macs <= 0:
            return 0.0
        co_tiles = math.ceil(n_out / self.f1)
        ci_tiles = math.ceil(n_in / self.f4)
        if self.style == "hsiao":
            px_tiles = math.ceil(pixels_out / (self.f2 * self.f3))
            k_cycles = math.ceil((kh * kw) / 9.0)
        else:
            px_tiles = math.ceil(pixels_out / self.f2)
            k_cycles = kh * math.ceil(kw / 3.0)
        return float(co_tiles * ci_tiles * px_tiles * k_cycles)

    # ---- Eq. (4) area --------------------------------------------------------
    def area_pe_um2(self) -> float:
        """A_PB: the PE-array area term of Eq. (4)."""
        per_pe = self.mults_per_pe * self.area_per_mult_um2 + self.area_per_pe_overhead_um2
        return self.n_pes * per_pe

    def area_um2(self, *, if_sram_words: float, w_sram_words: float,
                 of_sram_words: float, word_bytes: float = 1.0) -> float:
        """A = A_PB + A_IFM + A_WB + A_OFM (+ controller), Eq. (4)."""
        sram_bytes = (if_sram_words + w_sram_words + of_sram_words) * word_bytes
        return (
            self.area_pe_um2()
            + sram_bytes * self.area_per_sram_byte_um2
            + self.area_controller_um2
        )

    # ---- vectorisation helper -----------------------------------------------
    def as_row(self) -> np.ndarray:
        """Numeric row for the vmapped sweep (style encoded as mults_per_pe)."""
        return np.asarray(
            [
                self.f1,
                self.f2,
                self.f3,
                self.f4,
                self.mults_per_pe,
                self.dram_words_per_cycle,
                self.pipeline_latency,
                self.e_dram_nj,
                self.e_sram_nj,
                self.e_pb_nj,
                self.pe_units,
            ],
            dtype=np.float64,
        )

    ROW_FIELDS = (
        "f1", "f2", "f3", "f4", "mults_per_pe", "dram_words_per_cycle",
        "pipeline_latency", "e_dram_nj", "e_sram_nj", "e_pb_nj", "pe_units",
    )

    def describe(self) -> str:
        """One-line human-readable summary of the design point."""
        return (
            f"{self.style}(F1={self.f1},F2={self.f2},F3={self.f3},F4={self.f4})"
            f" {self.macs_per_cycle} MAC/cyc {self.n_pes} PEs"
        )


def default_config_space(
    *,
    styles: Sequence[str] = ARCH_STYLES,
    factors: Sequence[int] = (2, 4, 8, 16),
) -> list[DLAConfig]:
    """The predefined configuration set the optimisation flow sweeps."""
    out: list[DLAConfig] = []
    for style in styles:
        f3s = (3,) if style == "vwa" else factors
        for f1, f2, f3, f4 in itertools.product(factors, factors, f3s, factors):
            out.append(DLAConfig(style, f1, f2, f3, f4))
    return out


# SRAM banking presets for the design-space grid: splitting the frame
# buffers into more banks shortens bitlines/wordlines, cutting per-access
# energy (classic CACTI scaling; the paper's Sec. III constant 0.1 nJ is
# the unified calibration).  Only ``e_sram_nj`` varies — area constants
# stay shared across the space, which the sweep requires
# (:func:`repro.core.metrics.area_consts_of_space`).
SRAM_SPLITS = {
    "unified": 0.1,
    "banked2": 0.07,
    "banked4": 0.05,
}


def config_space_grid(
    *,
    styles: Sequence[str] = ARCH_STYLES,
    f1s: Sequence[int] = (2, 4, 8, 16),
    f2s: Sequence[int] = (2, 4, 8, 16),
    f3s: Sequence[int] = (2, 4, 8, 16),
    f4s: Sequence[int] = (2, 4, 8, 16),
    bus_widths: Sequence[int] = (2, 4, 8, 16),
    sram_splits: Sequence[str] = ("unified", "banked4"),
    pe_energy: str = "pe_cycle",
) -> list[DLAConfig]:
    """Parameterised design-space generator: PE-array shape x SRAM split x
    DRAM bus width -> thousands of :class:`DLAConfig` points.

    This grows the paper's handful of predefined configs into a
    LoopTree-style explorable design space: the defaults yield 2560 points
    (hsiao 4^4 + vwa 4^3 PE shapes, x4 bus widths, x2 SRAM splits), which
    :func:`repro.core.flow.run_fleet` sweeps in one XLA program —
    optionally sharded over a device mesh (``devices=``) since the
    hardware axis is embarrassingly parallel.

    ``bus_widths`` sets ``dram_words_per_cycle`` and should stay powers of
    two: every latency division is then exact in float64, preserving the
    sweep's bit-identity to the scalar oracles.  ``sram_splits`` are
    :data:`SRAM_SPLITS` preset names varying the per-access SRAM energy;
    area constants are deliberately NOT varied (the sweep shares one
    area-consts vector across the hardware batch).  vwa PE blocks are
    F2 x 3 by construction, so ``f3s`` applies to hsiao only.
    """
    out: list[DLAConfig] = []
    for style in styles:
        s_f3s = (3,) if style == "vwa" else f3s
        for split in sram_splits:
            if split not in SRAM_SPLITS:
                raise ConfigValidationError(
                    f"unknown SRAM-split preset {split!r}; "
                    f"valid presets: {sorted(SRAM_SPLITS)}")
            e_sram = SRAM_SPLITS[split]
            for bus in bus_widths:
                for f1, f2, f3, f4 in itertools.product(f1s, f2s, s_f3s, f4s):
                    out.append(
                        DLAConfig(
                            style, f1, f2, f3, f4,
                            pe_energy=pe_energy,
                            dram_words_per_cycle=bus,
                            e_sram_nj=e_sram,
                        )
                    )
    return out


# ---------------------------------------------------------------------------
# Constraints (Sec. II-C / Sec. III)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Constraints:
    """User constraints checked by the optimisation flow (paper Sec. III)."""

    max_bandwidth_words: float = 20e6  # 20 M bytes (1 word = 1 byte)
    max_latency_cycles: float = 12e6  # 12 M cycles
    max_energy_nj: float = 65e6  # 65 mJ
    max_area_um2: float = 45e6  # 45,000,000 um^2

    def as_row(self) -> np.ndarray:
        """The four bounds as a float64 row, metric order of Eq. (1)-(4)."""
        return np.asarray(
            [
                self.max_bandwidth_words,
                self.max_latency_cycles,
                self.max_energy_nj,
                self.max_area_um2,
            ],
            dtype=np.float64,
        )


PAPER_CONSTRAINTS = Constraints()
PAPER_OPTIMAL_CONFIG = DLAConfig("hsiao", 4, 4, 4, 4)


def paper_config_space() -> list[DLAConfig]:
    """The paper's 'predefined configuration set' (Sec. III).

    The paper does not list the set; uniform-factor configurations
    (F,F,F,F) per style are the natural reading under which its stated
    optimum (4,4,4,4) is the unique feasible min-energy point: (2,2,2,2)
    violates the 12 M-cycle latency bound, (16,16,16,16) the 45 mm^2 area
    bound, (8,8,8,8) is feasible but spends more PE energy on ceil-tiling
    waste, and every VWA point violates the 65 mJ energy bound (1 mult/PE
    => per-PE-cycle energy is per-MAC energy).  Validated in
    tests/test_flow.py.
    """
    out = [DLAConfig("hsiao", f, f, f, f) for f in (2, 4, 8, 16)]
    out += [DLAConfig("vwa", f, f, 3, f) for f in (2, 4, 8, 16)]
    return out


# ---------------------------------------------------------------------------
# TPU target (the hardware this framework actually runs the models on)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    """Per-chip TPU roofline parameters (compute/HBM/ICI peaks)."""

    name: str = "tpu_v5e"
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw_per_link: float = 50e9  # bytes/s per ICI link
    ici_links: int = 4  # torus links per chip used by collectives
    vmem_bytes: int = 128 * 1024 * 1024
    hbm_bytes: int = 16 * 1024 * 1024 * 1024
    mxu_dim: int = 128  # systolic array tile edge

    @property
    def ici_bw(self) -> float:
        """Aggregate interconnect bandwidth over all torus links."""
        return self.ici_bw_per_link * self.ici_links

    def compute_seconds(self, flops: float) -> float:
        """Compute-bound time at peak FLOP/s."""
        return flops / self.peak_flops

    def memory_seconds(self, hbm_bytes: float) -> float:
        """Memory-bound time at peak HBM bandwidth."""
        return hbm_bytes / self.hbm_bw

    def collective_seconds(self, coll_bytes: float) -> float:
        """Interconnect-bound time at aggregate ICI bandwidth."""
        return coll_bytes / self.ici_bw


TPU_V5E = TPUSpec()
