"""Fusion planner: the paper's optimization flow driving runtime kernels.

The paper's flow (Sec. II-C) picks hardware + layer-group configuration by
evaluating candidates against constraints.  Here the "hardware config" is
a Pallas kernel block shape and the "constraint" is the 128 MiB VMEM of a
v5e core: for each fusion group (attention, MLP, conv, SSM scan) the
planner enumerates candidate block shapes (MXU-aligned, multiples of 128),
rejects those whose VMEM working set does not fit, and picks the feasible
candidate minimising predicted HBM traffic (Eq. (1) with VMEM in place of
SRAM) — then the model stack executes that choice via repro.kernels.ops.

``plan_model`` also runs the *layer-grouping* half of the flow over the
architecture's transformer-block IR (repro.core.ir.transformer_block_ir)
to report the per-block bandwidth saving of fused vs. layer-by-layer
execution — the numbers in benchmarks table5/table6.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from .arch import TPU_V5E, TPUSpec
from . import fusion
from . import ir as IR
from . import metrics as M

MXU = 128


@functools.lru_cache(maxsize=256)
def _block_bandwidths(
    name: str,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    d_ff: int,
    seq_len: int,
    ffn_act: str,
    n_experts: int,
    top_k: int,
) -> tuple[float, float, str]:
    """(layer-by-layer, fused, engine) Eq. (1) bandwidth of one transformer
    block plus the search-engine provenance of the fused grouping.

    Memoised on the block-shaping config fields + seq_len: building the
    block IR and running ``optimal_cuts`` dominate ``plan_model``, and every
    caller (quickstart, benchmarks, repeated planning in a serve loop) asks
    for the same few (cfg, seq_len) points — repeats are a cache hit.
    """
    block_ir = IR.as_graph(IR.transformer_block_ir(
        name=name, d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv_heads,
        d_ff=d_ff, seq_len=seq_len, ffn_act=ffn_act, n_experts=n_experts,
        top_k=top_k,
    ))
    # fused grouping: {q,kv} | {qk, pv} (flash) | {o} | {w1/w3, w2} (fused MLP)
    dp = fusion.optimal_cuts(block_ir)
    # Both groupings scored in one batched-evaluator call (lock-step with
    # bandwidth_ref, so the reported saving is unchanged).
    bws = M.bandwidth_batch_graph(
        block_ir, np.stack([fusion.layer_by_layer_cuts(block_ir), dp.cuts])
    )
    return float(bws[0]), float(bws[1]), dp.engine


@dataclasses.dataclass(frozen=True)
class FusionPlan:
    """Kernel tile/block choices for one (arch, seq_len) plus the
    evaluator's fused-vs-layer-by-layer bandwidth verdict."""

    arch: str
    seq_len: int
    # attention
    use_flash: bool
    attn_block_q: int
    attn_block_k: int
    attn_vmem_bytes: int
    # mlp
    use_fused_mlp: bool
    mlp_block_m: int
    mlp_block_f: int
    mlp_vmem_bytes: int
    # ssm
    mamba_chunk: int
    mamba_block_d: int
    # conv (vgg path)
    conv_block_c: int
    # evaluator outputs
    bw_fused_words: float
    bw_lbl_words: float
    # grouping-search provenance ("chain_dp" for transformer block chains;
    # "frontier_dp"/"beam" would signal a DAG-shaped block IR)
    search_engine: str = ""

    @property
    def bw_saving(self) -> float:
        """Fractional DRAM-traffic reduction of fused over lbl."""
        return 1.0 - self.bw_fused_words / max(self.bw_lbl_words, 1.0)

    def describe(self) -> str:
        """One-line tiling + bandwidth-saving summary."""
        return (
            f"{self.arch}@{self.seq_len}: flash({self.attn_block_q}x"
            f"{self.attn_block_k}, {self.attn_vmem_bytes/2**20:.1f}MiB) "
            f"mlp({self.mlp_block_m}x{self.mlp_block_f}, "
            f"{self.mlp_vmem_bytes/2**20:.1f}MiB) "
            f"block-BW saving {self.bw_saving*100:.1f}%"
        )


def _plan_attention(hd: int, seq: int, spec: TPUSpec):
    """Largest MXU-aligned (block_q, block_k) whose working set fits VMEM/4
    (leave headroom for double buffering + other live buffers)."""
    from ..kernels.fused_attention import vmem_bytes

    budget = spec.vmem_bytes // 4
    best = (MXU, MXU, vmem_bytes(MXU, MXU, hd))
    for bq in (128, 256, 512, 1024):
        for bk in (128, 256, 512, 1024):
            if bq > seq or bk > seq:
                continue
            b = vmem_bytes(bq, bk, hd)
            if b <= budget and bq * bk > best[0] * best[1]:
                best = (bq, bk, b)
    return best


def _plan_mlp(d: int, ff: int, spec: TPUSpec):
    from ..kernels.fused_mlp import vmem_bytes

    budget = spec.vmem_bytes // 4
    best = None
    for bm in (128, 256, 512):
        for bf in (128, 256, 512, 1024, 2048):
            if bf > ff:
                continue
            b = vmem_bytes(bm, bf, d)
            if b <= budget and (best is None or bm * bf > best[0] * best[1]):
                best = (bm, bf, b)
    if best is None:  # d too large for any tile: fall back to minimum
        best = (MXU, MXU, vmem_bytes(MXU, MXU, d))
    return best


def plan_model(cfg, seq_len: int, spec: TPUSpec = TPU_V5E) -> FusionPlan:
    """Plan kernel tilings for one config and score fused vs lbl traffic."""
    hd = cfg.resolved_head_dim
    bq, bk, attn_b = _plan_attention(hd, seq_len, spec)
    bm, bf, mlp_b = _plan_mlp(cfg.d_model, max(cfg.d_ff, cfg.d_model), spec)

    # Evaluator pass over one transformer block: fused vs layer-by-layer BW.
    # The block chain embeds as a GraphIR so the same edge-cut search that
    # handles residual DAGs drives kernel selection here (chain DP fast
    # path); memoised per (cfg shape, seq_len) so repeated planning of the
    # same model is an evaluator-cache hit.
    lbl, fused, engine = _block_bandwidths(
        cfg.name, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
        max(cfg.d_ff, 1), seq_len, cfg.ffn_act, cfg.n_experts, cfg.top_k,
    )

    return FusionPlan(
        arch=cfg.name,
        seq_len=seq_len,
        use_flash=True,
        attn_block_q=bq,
        attn_block_k=bk,
        attn_vmem_bytes=attn_b,
        use_fused_mlp=cfg.d_ff > 0,
        mlp_block_m=bm,
        mlp_block_f=bf,
        mlp_vmem_bytes=mlp_b,
        mamba_chunk=64,
        mamba_block_d=min(512, cfg.d_inner),
        conv_block_c=64,
        bw_fused_words=fused,
        bw_lbl_words=lbl,
        search_engine=engine,
    )
