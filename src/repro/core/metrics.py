"""Evaluation metrics — Eq. (1)-(4) of the paper, in edge-cut semantics.

Two implementations, kept deliberately in lock-step (tests assert equality):

* ``*_ref``      — direct, readable transcriptions of the equations operating
  on :class:`repro.core.ir.GraphIR` (or a chain :class:`repro.core.ir.NetworkIR`,
  embedded losslessly via :func:`repro.core.ir.as_graph`) + a cut vector.
  These are the oracle.
* ``evaluate_batch_graph`` — a vectorised jnp version broadcast over a batch
  of hardware configurations (H) x a batch of fusion groupings (C), so the
  paper's exhaustive optimisation flow (Sec. II-C) runs as ONE jitted XLA
  program instead of a Python loop over ~5 M candidates.  Optional
  ``node_mask``/``edge_mask`` arguments admit zero-padded inputs (shape
  buckets, :func:`repro.core.ir.pad_graph`) with padded rows exactly inert;
  ``evaluate_fleet_graph`` adds a leading graph axis so a whole fleet of
  padded graphs evaluates as a single program (:mod:`repro.core.flow`).
  ``evaluate_batch`` is the chain-shaped wrapper kept for the original
  (L, F) x (C, L-1) call signature.

Grouping representation: a boolean *cut vector* over the graph's **edges**
(canonically sorted by ``(src, dst)``).  ``cuts[k]`` True means edge ``k``
crosses a fusion-group boundary.  The cost model per Eq. (1)-(4):

* a **cut** edge costs DRAM on both ends — the producer writes its output
  frame once (however many cut consumers it feeds), and each cut consumer
  reads the edge's ``words`` back;
* an **internal** (uncut) edge costs only SRAM: the tensor ping-pongs
  between the on-chip frame buffers and never touches DRAM, but its
  *pre-pool* frame must fit on chip (Eq. (4) sizing);
* source nodes always read their input frame from DRAM; sink nodes always
  write their output frame.

On a chain embedding (edge ``i`` = layer ``i`` -> ``i+1``) this reduces
exactly to the paper's per-group ``in_first + out_last`` accounting:
layer-by-layer execution is ``cuts = all True``; whole-network fusion is
``all False``.  See :mod:`repro.core.ir` for an ASCII picture of a residual
block's cut space.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .errors import ConfigValidationError, GraphValidationError
from jax.experimental import enable_x64

from .arch import DLAConfig
from .ir import GraphIR, NetworkIR, as_graph

# Staging buffer (words) for tiles streamed directly from/to DRAM at group
# edges — a group's first input and last output never need full-frame SRAM.
STAGING_WORDS = 4096.0


def group_masks(cuts: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(start, end) boolean masks of shape (L,) from a chain cut vector (L-1,)."""
    cuts = np.asarray(cuts, dtype=bool)
    L = cuts.shape[0] + 1
    start = np.concatenate([[True], cuts])
    end = np.concatenate([cuts, [True]])
    assert start.shape == (L,) and end.shape == (L,)
    return start, end


def groups_from_cuts(cuts: np.ndarray) -> list[list[int]]:
    """Explicit group index lists (for printing / brute-force tests)."""
    start, _ = group_masks(cuts)
    groups: list[list[int]] = []
    for i, s in enumerate(start):
        if s:
            groups.append([i])
        else:
            groups[-1].append(i)
    return groups


def edge_io_masks(g: GraphIR, cuts: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(reads_input, writes_output) node masks of shape (L,) for a cut vector.

    ``reads_input[i]``  — node i streams its *external* input frame from DRAM
    (only source nodes; cut-edge reads are accounted per edge, not here).
    ``writes_output[i]`` — node i writes its output frame to DRAM (sink node,
    or at least one outgoing edge is cut).
    """
    cuts = np.asarray(cuts, dtype=bool)
    if cuts.shape != (g.n_edges,):
        raise ValueError(f"cut vector shape {cuts.shape} != (E={g.n_edges},)")
    reads = g.source_mask.copy()
    writes = g.sink_mask.copy()
    for k, e in enumerate(g.edges):
        if cuts[k]:
            writes[e.src] = True
    return reads, writes


# ---------------------------------------------------------------------------
# Reference implementations (the paper's equations in edge-cut form)
# ---------------------------------------------------------------------------


def bandwidth_ref(ir: NetworkIR | GraphIR, cuts: np.ndarray) -> float:
    """Eq. (1): BW = sum_p { sum_q {N Nkh Nkw M}_Lpq + N Nih Niw + Noh Now M }_Lp.

    Edge-cut form: every node's weights stream from DRAM; every source node
    reads its input frame (plus any node's ``ext_in_words`` — edge-less
    operands re-read in every grouping); every cut edge is read back by its
    consumer; every node with a cut outgoing edge (or no consumer) writes
    its output frame once.
    """
    g = as_graph(ir)
    cuts = np.asarray(cuts, dtype=bool)
    reads, writes = edge_io_masks(g, cuts)
    bw = 0.0
    for i, n in enumerate(g.nodes):
        bw += n.weight_words  # every layer's weights stream from DRAM
        bw += n.ext_in_words  # edge-less activation operands (always DRAM)
        if reads[i]:
            bw += n.in_words  # external input frame read
        if writes[i]:
            bw += n.out_words  # group output frame write
    for k, e in enumerate(g.edges):
        if cuts[k]:
            bw += e.words  # cut tensor read back by the consumer
    return bw


def latency_ref(ir: NetworkIR | GraphIR, cuts: np.ndarray, hw: DLAConfig) -> float:
    """Eq. (2): L = sum_p { sum_q {t_rd_W + t_PB + t_PL}_Lpq + t_rd_IF + t_wr_OF }_Lp."""
    g = as_graph(ir)
    cuts = np.asarray(cuts, dtype=bool)
    reads, writes = edge_io_masks(g, cuts)
    lat = 0.0
    for i, n in enumerate(g.nodes):
        lat += n.weight_words / hw.dram_words_per_cycle  # t_rd_W
        lat += hw.pe_busy_cycles(  # t_PB
            macs=n.macs,
            n_in=n.contracted_channels,
            n_out=n.n_out,
            kh=n.kh,
            kw=n.kw,
            pixels_out=(n.h_in // n.stride) * (n.w_in // n.stride),
        )
        lat += hw.pipeline_latency  # t_PL
        lat += n.ext_in_words / hw.dram_words_per_cycle
        if reads[i]:
            lat += n.in_words / hw.dram_words_per_cycle  # t_rd_IF
        if writes[i]:
            lat += n.out_words / hw.dram_words_per_cycle  # t_wr_OF
    for k, e in enumerate(g.edges):
        if cuts[k]:
            lat += e.words / hw.dram_words_per_cycle  # cut tensor read back
    return lat


def sram_accesses_ref(ir: NetworkIR | GraphIR) -> float:
    """C_SRAM: every layer operand passes on-chip SRAM exactly once,
    independent of grouping (fusion only changes what *also* touches DRAM).

    A node's input traffic is max(in_words, sum of incoming edge words +
    edge-less ``ext_in_words``): multi-input nodes (ResNet add) stream
    every fused operand through SRAM even though ``in_words`` describes a
    single frame, while chain embeddings (one edge carrying exactly
    ``in_words``) are unchanged.
    """
    g = as_graph(ir)
    in_edge = np.zeros(len(g.nodes))
    for e in g.edges:
        in_edge[e.dst] += e.words
    return float(
        sum(
            n.weight_words
            + max(n.in_words, in_edge[i] + n.ext_in_words)
            + n.out_words
            for i, n in enumerate(g.nodes)
        )
    )


def pe_energy_count_ref(ir: NetworkIR | GraphIR, hw: DLAConfig) -> float:
    """C_PE: busy cycles x pe_units (per-PE-cycle or per-block-cycle)."""
    g = as_graph(ir)
    total = 0.0
    for n in g.nodes:
        total += hw.pe_busy_cycles(
            macs=n.macs,
            n_in=n.contracted_channels,
            n_out=n.n_out,
            kh=n.kh,
            kw=n.kw,
            pixels_out=(n.h_in // n.stride) * (n.w_in // n.stride),
        )
    return total * hw.pe_units


# Back-compat alias (pre-calibration name).
pe_block_cycles_ref = pe_energy_count_ref


def energy_ref(ir: NetworkIR | GraphIR, cuts: np.ndarray, hw: DLAConfig) -> float:
    """Eq. (3): E = E_DRAM*C_DRAM + E_SRAM*C_SRAM + E_PB*C_PB   [nJ]."""
    c_dram = bandwidth_ref(ir, cuts)
    c_sram = sram_accesses_ref(ir)
    c_pb = pe_energy_count_ref(ir, hw)
    return hw.e_dram_nj * c_dram + hw.e_sram_nj * c_sram + hw.e_pb_nj * c_pb


def buffer_words_ref(
    ir: NetworkIR | GraphIR, cuts: np.ndarray
) -> tuple[float, float, float]:
    """SRAM sizing (IF, W, OF) in words for Eq. (4).

    Fused intermediates ping-pong between the input and output frame SRAMs;
    group-edge tensors stream through small staging buffers.  A node's IF
    SRAM must hold *all* of its internal incoming tensors simultaneously
    (one per uncut edge); its OF SRAM must hold the **pre-pool** output
    frame whenever any consumer is fused with it — the inline pool unit
    (Fig. 1) reduces the frame only on the DRAM write-out path, so a fused
    consumer sees the full pre-pool intermediate.  A recurrent node's
    ``state_words`` carry lives in IF SRAM for its whole execution, on top
    of whatever input it streams, in every grouping.  Weight SRAM holds the
    largest single layer's kernels.
    """
    g = as_graph(ir)
    cuts = np.asarray(cuts, dtype=bool)
    if_need, of_need = STAGING_WORDS, STAGING_WORDS
    internal_in = np.zeros(len(g.nodes))
    internal_out = np.zeros(len(g.nodes), dtype=bool)
    for k, e in enumerate(g.edges):
        if not cuts[k]:
            internal_in[e.dst] += e.words
            internal_out[e.src] = True
    for i, n in enumerate(g.nodes):
        src = internal_in[i] if internal_in[i] > 0 else STAGING_WORDS
        src += float(n.state_words)
        dst = float(n.out_words_prepool) if internal_out[i] else STAGING_WORDS
        if_need = max(if_need, src)
        of_need = max(of_need, dst)
    w_need = max(float(n.weight_words) for n in g.nodes)
    return float(if_need), float(w_need), float(of_need)


def area_ref(ir: NetworkIR | GraphIR, cuts: np.ndarray, hw: DLAConfig) -> float:
    """Eq. (4): A = A_PB + A_IFM + A_WB + A_OFM   [um^2]."""
    if_w, w_w, of_w = buffer_words_ref(ir, cuts)
    return hw.area_um2(if_sram_words=if_w, w_sram_words=w_w, of_sram_words=of_w)


@dataclasses.dataclass(frozen=True)
class Metrics:
    """The paper's four scores for one (graph, grouping, hw) candidate."""

    bandwidth_words: float
    latency_cycles: float
    energy_nj: float
    area_um2: float

    def meets(self, c) -> bool:
        """All four metrics within the :class:`Constraints` bounds."""
        return (
            self.bandwidth_words <= c.max_bandwidth_words
            and self.latency_cycles <= c.max_latency_cycles
            and self.energy_nj <= c.max_energy_nj
            and self.area_um2 <= c.max_area_um2
        )


def evaluate_ref(ir: NetworkIR | GraphIR, cuts: np.ndarray, hw: DLAConfig) -> Metrics:
    """Scalar-oracle Eq. (1)-(4) for one candidate (the lock-step ref)."""
    return Metrics(
        bandwidth_words=bandwidth_ref(ir, cuts),
        latency_cycles=latency_ref(ir, cuts, hw),
        energy_nj=energy_ref(ir, cuts, hw),
        area_um2=area_ref(ir, cuts, hw),
    )


# ---------------------------------------------------------------------------
# Batched numpy kernels — the search engine's scoring path
# ---------------------------------------------------------------------------
#
# The grouping search evaluates (C, E) cut batches thousands of times with a
# different C every round, so it scores with plain numpy (no per-shape XLA
# recompile, no dispatch overhead); `evaluate_batch_graph` below remains the
# jitted evaluator for the final (hw x grouping) sweep.  All sums here are of
# integer-valued float64 words (< 2^53), so the batched kernels are exactly
# equal to the scalar oracles, not just approximately (locked in tests).


@dataclasses.dataclass(frozen=True)
class GraphArrays:
    """Cached numpy views of a GraphIR consumed by the batched kernels."""

    feat: np.ndarray  # (L, F)
    esrc: np.ndarray  # (E,)
    edst: np.ndarray  # (E,)
    ewords: np.ndarray  # (E,)
    src_mask: np.ndarray  # (L,) bool
    sink_mask: np.ndarray  # (L,) bool
    inc_src: np.ndarray  # (E, L) 1.0 at [k, esrc[k]]
    win_dst: np.ndarray  # (E, L) ewords[k] at [k, edst[k]]
    out_edges: tuple[np.ndarray, ...]  # per node: its outgoing edge indices
    base_bw: float  # weights + unconditional source-frame reads


def graph_arrays(g: GraphIR) -> GraphArrays:
    """Per-instance memo (GraphIR is immutable, so this can never go stale);
    an attribute lookup rather than an lru_cache so the hot search loops do
    not re-hash the whole graph on every scoring call."""
    ga = g.__dict__.get("_graph_arrays")
    if ga is not None:
        return ga
    feat = g.node_features()
    esrc, edst, ewords = g.edge_arrays()
    E, L = len(esrc), len(g.nodes)
    inc_src = np.zeros((E, L))
    inc_src[np.arange(E), esrc] = 1.0
    win_dst = np.zeros((E, L))
    win_dst[np.arange(E), edst] = ewords
    out_edges = tuple(np.flatnonzero(esrc == i) for i in range(L))
    src_mask, sink_mask = g.source_mask, g.sink_mask
    base_bw = float(
        feat[:, F_W].sum() + feat[:, F_EXT].sum() + feat[src_mask, F_IN].sum()
    )
    ga = GraphArrays(
        feat=feat, esrc=esrc, edst=edst, ewords=ewords, src_mask=src_mask,
        sink_mask=sink_mask, inc_src=inc_src, win_dst=win_dst,
        out_edges=out_edges, base_bw=base_bw,
    )
    object.__setattr__(g, "_graph_arrays", ga)
    return ga


@dataclasses.dataclass(frozen=True)
class PrefixCostTables:
    """Per-node views of the grouping-dependent Eq. (1) terms, organised so
    the cost of a *prefix* of edge decisions is exactly decomposable.

    Sweeping nodes in any topological order and deciding each node's
    incoming edges as it arrives, Eq. (1) bandwidth (minus the
    grouping-independent weights, captured in ``const_words``) accumulates
    in exact per-decision increments:

    * a cut edge adds its ``words`` (the consumer's DRAM read-back), plus
      the producer's ``out_words`` **iff** this is the producer's first cut
      out-edge (the output frame is written once however many cut
      consumers it feeds);
    * a sink node adds its ``sink_charge`` unconditionally when processed;
    * an uncut edge adds nothing — but its words join the consumer's
      internal-input sum and put the producer's ``prepool_words`` frame on
      chip, the two Eq. (4)-style terms ``graph_max_intermediate`` bounds.

    This is the table set behind the frontier-state DP
    (:func:`repro.core.fusion.frontier_dp_min_bw`): all quantities are
    integer-valued float64 words, so the accumulated cost is bit-identical
    to :func:`bandwidth_ref` minus the weights, not approximately equal.
    """

    in_edges: tuple[np.ndarray, ...]  # per node: incoming edge indices
    in_srcs: tuple[np.ndarray, ...]  # per node: those edges' producers
    in_words: tuple[np.ndarray, ...]  # per node: those edges' words
    out_words: np.ndarray  # (L,) output frame (post-pool) words
    prepool_words: np.ndarray  # (L,) on-chip pre-pool frame words
    sink_charge: np.ndarray  # (L,) out_words where sink else 0.0
    const_words: float  # sources + ext reads (Eq. (1) minus weights)
    state_words: np.ndarray  # (L,) recurrent carry held in SRAM per node


def graph_prefix_tables(g: GraphIR) -> PrefixCostTables:
    """Per-instance memo of :class:`PrefixCostTables` (same discipline as
    :func:`graph_arrays`: GraphIR is immutable, so this can never go
    stale)."""
    pt = g.__dict__.get("_prefix_tables")
    if pt is not None:
        return pt
    ga = graph_arrays(g)
    L = len(g.nodes)
    in_edges = tuple(np.flatnonzero(ga.edst == i) for i in range(L))
    pt = PrefixCostTables(
        in_edges=in_edges,
        in_srcs=tuple(ga.esrc[ks] for ks in in_edges),
        in_words=tuple(ga.ewords[ks] for ks in in_edges),
        out_words=ga.feat[:, F_OUT].copy(),
        prepool_words=ga.feat[:, F_OUT_PRE].copy(),
        sink_charge=np.where(ga.sink_mask, ga.feat[:, F_OUT], 0.0),
        const_words=ga.base_bw - float(ga.feat[:, F_W].sum()),
        state_words=ga.feat[:, F_STATE].copy(),
    )
    object.__setattr__(g, "_prefix_tables", pt)
    return pt


def bandwidth_batch_graph(
    ir: NetworkIR | GraphIR, cuts_batch: np.ndarray
) -> np.ndarray:
    """(C,) Eq. (1) bandwidth for a (C, E) cut batch — bit-identical to
    :func:`bandwidth_ref` per row, with no per-candidate Python."""
    g = as_graph(ir)
    ga = graph_arrays(g)
    cuts = np.atleast_2d(np.asarray(cuts_batch, dtype=bool))
    cutf = cuts.astype(np.float64)
    writes = (cutf @ ga.inc_src) > 0.0  # (C, L): >= 1 cut outgoing edge
    writes |= ga.sink_mask[None, :]
    return (
        ga.base_bw
        + cutf @ ga.ewords  # cut tensors read back by their consumers
        + writes.astype(np.float64) @ ga.feat[:, F_OUT]
    )

# Feature column indices (must match NetworkIR.FEATURES order).
(F_W, F_IN, F_OUT, F_OUT_PRE, F_MACS, F_ISPOOL, F_KH, F_KW, F_NIN, F_NOUT,
 F_PIX, F_EXT, F_STATE) = range(13)
# HW row indices (must match DLAConfig.ROW_FIELDS order).
(H_F1, H_F2, H_F3, H_F4, H_MPP, H_DWPC, H_TPL, H_EDRAM, H_ESRAM, H_EPB,
 H_PEU) = range(11)


def _ceil_div(a, b):
    return jnp.ceil(a / b)


def _pe_busy_cycles_vec(feat: jnp.ndarray, hw: jnp.ndarray) -> jnp.ndarray:
    """t_PB per layer, (L,) given one hw row — branch on PE style."""
    co = _ceil_div(feat[:, F_NOUT], hw[H_F1])
    ci = _ceil_div(feat[:, F_NIN], hw[H_F4])
    px_h = _ceil_div(feat[:, F_PIX], hw[H_F2] * hw[H_F3])  # hsiao: F2*F3 pixels
    kc_h = _ceil_div(feat[:, F_KH] * feat[:, F_KW], 9.0)
    px_v = _ceil_div(feat[:, F_PIX], hw[H_F2])  # vwa: F2 rows
    kc_v = feat[:, F_KH] * _ceil_div(feat[:, F_KW], 3.0)
    is_hsiao = hw[H_MPP] == 9
    cyc = jnp.where(is_hsiao, co * ci * px_h * kc_h, co * ci * px_v * kc_v)
    return jnp.where(feat[:, F_MACS] > 0, cyc, 0.0)


def _evaluate_one_graph(
    feat: jnp.ndarray,  # (L, F)
    esrc: jnp.ndarray,  # (E,) int
    edst: jnp.ndarray,  # (E,) int
    ewords: jnp.ndarray,  # (E,) float
    src_mask: jnp.ndarray,  # (L,) bool — in-degree 0
    sink_mask: jnp.ndarray,  # (L,) bool — out-degree 0
    cuts: jnp.ndarray,  # (E,) bool
    hw: jnp.ndarray,
    area_consts: jnp.ndarray,
    node_mask: jnp.ndarray,  # (L,) bool — False on padded node rows
    edge_mask: jnp.ndarray,  # (E,) bool — False on padded edge slots
) -> jnp.ndarray:
    """Raw row for one (grouping, hw) pair -> (5,) [bw, lat, c_sram, c_pb,
    area]; :func:`compose_metrics` turns it into [bw, lat, energy, area].

    Energy is deliberately NOT composed here: every quantity this kernel
    emits is exact in float64 (integer-valued sums; latency divides only by
    the power-of-two bus width; all area constants are dyadic), so results
    are bit-identical across program shapes — but ``e_sram``/``e_pb`` are
    non-dyadic, and XLA's freedom to FMA-fuse ``mul+add`` differently in
    the batch vs the vmapped fleet program would make an in-kernel energy
    differ between the two by an ulp.  Composing outside XLA (numpy) keeps
    every compiled variant bit-identical to the scalar oracles.

    ``node_mask``/``edge_mask`` admit zero-padded inputs (shape buckets, see
    :func:`repro.core.ir.pad_graph`): a padded edge is neither cut nor
    internal regardless of its ``cuts`` bit, and a padded node contributes
    no pipeline latency.  Padded feature rows are all-zero, so with the
    masks every padded term is exactly 0.0 (or the STAGING_WORDS floor in
    the Eq. (4) maxes) and padded evaluation is bit-identical to unpadded
    (integer-valued float64 words sum exactly in any order).
    """
    L = feat.shape[0]
    # A padded edge is inert on both sides of the cut/internal split.
    cut_real = cuts & edge_mask
    internal_real = (~cuts) & edge_mask
    cutf = cut_real.astype(feat.dtype)

    # Node write mask: sink, or >= 1 cut outgoing edge (scatter-max over src).
    any_out_cut = jnp.zeros(L, feat.dtype).at[esrc].max(cutf) > 0.5
    writes = any_out_cut | sink_mask

    # Eq. (1) — ext_in_words are edge-less operands, read in every grouping
    read_src = jnp.sum(jnp.where(src_mask, feat[:, F_IN], 0.0)) + jnp.sum(
        feat[:, F_EXT]
    )
    read_edges = jnp.sum(jnp.where(cut_real, ewords, 0.0))
    write_out = jnp.sum(jnp.where(writes, feat[:, F_OUT], 0.0))
    bw = jnp.sum(feat[:, F_W]) + read_src + read_edges + write_out

    # Eq. (2) — pipeline latency counts real nodes, not the padded shape
    t_pb = _pe_busy_cycles_vec(feat, hw)
    n_real = jnp.sum(node_mask.astype(feat.dtype))
    lat = (
        jnp.sum(feat[:, F_W]) / hw[H_DWPC]
        + jnp.sum(t_pb)
        + n_real * hw[H_TPL]
        + (read_src + read_edges) / hw[H_DWPC]
        + write_out / hw[H_DWPC]
    )

    # Eq. (3) — per-node input SRAM traffic is max(in_words, incoming edges)
    # so multi-input nodes count every operand (see sram_accesses_ref).
    in_edge = jnp.zeros(L, feat.dtype).at[edst].add(
        jnp.where(edge_mask, ewords, 0.0)
    )
    c_sram = jnp.sum(
        feat[:, F_W]
        + jnp.maximum(feat[:, F_IN], in_edge + feat[:, F_EXT])
        + feat[:, F_OUT]
    )
    c_pb = jnp.sum(t_pb) * hw[H_PEU]

    # Eq. (4): internal incoming tensors coexist in IF SRAM; a node with any
    # fused consumer holds its *pre-pool* frame in OF SRAM.
    internal_in = jnp.zeros(L, feat.dtype).at[edst].add(
        jnp.where(internal_real, ewords, 0.0)
    )
    any_out_internal = (
        jnp.zeros(L, feat.dtype).at[esrc].max(internal_real.astype(feat.dtype))
        > 0.5
    )
    src_need = (
        jnp.where(internal_in > 0, internal_in, STAGING_WORDS)
        + feat[:, F_STATE]
    )
    dst_need = jnp.where(any_out_internal, feat[:, F_OUT_PRE], STAGING_WORDS)
    if_need = jnp.maximum(jnp.max(src_need), STAGING_WORDS)
    of_need = jnp.maximum(jnp.max(dst_need), STAGING_WORDS)
    w_need = jnp.max(feat[:, F_W])
    a_mult, a_pe_ovh, a_byte, a_ctrl = area_consts
    n_pes = hw[H_F1] * hw[H_F4] * hw[H_F2] * hw[H_F3]
    area = (
        n_pes * (hw[H_MPP] * a_mult + a_pe_ovh)
        + (if_need + w_need + of_need) * a_byte
        + a_ctrl
    )
    return jnp.stack([bw, lat, c_sram, c_pb, area])


def _evaluate_batch_graph(
    feat: jnp.ndarray,  # (L, F) float
    esrc: jnp.ndarray,  # (E,) int
    edst: jnp.ndarray,  # (E,) int
    ewords: jnp.ndarray,  # (E,) float
    src_mask: jnp.ndarray,  # (L,) bool
    sink_mask: jnp.ndarray,  # (L,) bool
    cuts_batch: jnp.ndarray,  # (C, E) bool
    hw_rows: jnp.ndarray,  # (H, 11) float
    area_consts: jnp.ndarray,  # (4,) float
    node_mask: jnp.ndarray | None = None,  # (L,) bool; None = no padding
    edge_mask: jnp.ndarray | None = None,  # (E,) bool; None = no padding
) -> jnp.ndarray:
    """Unjitted kernel body -> RAW (H, C, 5) rows (eager path for tests);
    :func:`compose_metrics` folds them to (H, C, 4) metrics."""
    if node_mask is None:
        node_mask = jnp.ones(feat.shape[0], dtype=bool)
    if edge_mask is None:
        edge_mask = jnp.ones(esrc.shape[0], dtype=bool)
    per_cut = jax.vmap(
        _evaluate_one_graph,
        in_axes=(None, None, None, None, None, None, 0, None, None, None, None),
    )
    per_hw = jax.vmap(
        per_cut,
        in_axes=(None, None, None, None, None, None, None, 0, None, None, None),
    )
    return per_hw(
        feat, esrc, edst, ewords, src_mask, sink_mask, cuts_batch, hw_rows,
        area_consts, node_mask, edge_mask,
    )


# Jitted kernels (used AOT by repro.core.flow, always under enable_x64).
# They return RAW (…, 5) rows; compose_metrics folds them to (…, 4).
_jit_batch_graph = jax.jit(_evaluate_batch_graph)


def compose_metrics(raw, hw_rows) -> np.ndarray:
    """(…, H, C, 5) raw kernel rows -> (…, H, C, 4) [bw, lat, energy, area].

    Eq. (3) is composed here, outside XLA, in numpy: separate multiply and
    add passes cannot be FMA-fused, so every compiled kernel variant
    (exact-shape, shape-bucketed, vmapped fleet) yields bit-identical
    energy — and the term order matches :func:`energy_ref` exactly.
    """
    raw = np.asarray(raw)
    hw = np.asarray(hw_rows)
    bw, lat, c_sram, c_pb, area = np.moveaxis(raw, -1, 0)
    # (H, 1) factors broadcast against (…, H, C) metric planes.
    e_dram = hw[:, H_EDRAM, None]
    e_sram = hw[:, H_ESRAM, None]
    e_pb = hw[:, H_EPB, None]
    energy = e_dram * bw + e_sram * c_sram + e_pb * c_pb
    return np.stack([bw, lat, energy, area], axis=-1)


# ---------------------------------------------------------------------------
# Finite guard — poison detection on raw result planes
# ---------------------------------------------------------------------------

# The bit-identity discipline: every raw kernel row is an exact
# integer-valued float64, so any count above 2^53 has silently lost ulps
# and the "bit-identical across kernel variants" guarantee is void.
MAX_EXACT_WORDS = float(2 ** 53)


def poison_mask(raw) -> np.ndarray:
    """(…, 5) raw kernel rows -> (…,) bool mask of *poisoned* cells.

    A cell (one [bw, lat, c_sram, c_pb, area] row) is poisoned when any
    entry is NaN, +/-Inf, negative, or above ``2**53`` (the largest f64
    magnitude at which integer word counts are still exact) — any such
    row would silently corrupt the argmin / Pareto composition, so
    :mod:`repro.core.flow` excludes these cells *before* selection and
    reports them with (g, h, c) provenance instead.
    """
    raw = np.asarray(raw)
    bad = ~np.isfinite(raw) | (raw < 0.0) | (raw > MAX_EXACT_WORDS)
    return np.any(bad, axis=-1)


def assert_exact_f64(arr, *, what: str = "feature table") -> None:
    """Assert ``arr`` holds exactly-representable f64 word counts.

    The evaluator's equality-to-oracle guarantee assumes every feature /
    edge-word entry is a finite, non-negative, integer-valued float64
    below ``2**53``.  The giant-config zoo graphs (llama4 / arctic edge
    words reach ~1e10) are well inside that range, but a corrupted or
    overflowed table would break bit-identity silently — fail loudly at
    the sweep boundary instead.  Raises :class:`GraphValidationError`
    naming ``what`` and the first offending flat index.
    """
    a = np.asarray(arr, dtype=np.float64)
    bad = ~np.isfinite(a) | (a < 0.0) | (a > MAX_EXACT_WORDS) | (
        a != np.floor(a)
    )
    if bad.any():
        idx = int(np.flatnonzero(bad.ravel())[0])
        raise GraphValidationError(
            f"{what} is not exactly representable in f64: entry at flat "
            f"index {idx} is {a.ravel()[idx]!r} (must be a finite, "
            f"non-negative integer <= 2**53 for bit-exact evaluation)"
        )


def evaluate_batch_graph(
    feat,
    esrc,
    edst,
    ewords,
    src_mask,
    sink_mask,
    cuts_batch,
    hw_rows,
    area_consts,
    node_mask=None,
    edge_mask=None,
) -> np.ndarray:
    """All metrics for every (hw, grouping) pair -> (H, C, 4).

    The optional node/edge masks admit zero-padded (shape-bucketed) inputs;
    with masks of all-True (or None) this is exactly the unpadded evaluator.

    Evaluation runs under a *scoped* ``enable_x64`` (the global JAX config
    is untouched), so the dtype follows the inputs: float64 numpy arrays —
    the flow's path — evaluate in float64 and are **bit-identical** to the
    scalar ``*_ref`` oracles (all words are integer-valued, every division
    is by the power-of-two DRAM bus width, energy is composed outside XLA
    by :func:`compose_metrics`, and multiplication order matches the
    oracles term for term); pre-converted float32 ``jnp`` arrays keep
    float32 semantics.
    """
    with enable_x64():
        raw = _jit_batch_graph(
            feat, esrc, edst, ewords, src_mask, sink_mask, cuts_batch,
            hw_rows, area_consts, node_mask, edge_mask,
        )
    return compose_metrics(raw, hw_rows)


def _evaluate_fleet_graph(
    feat: jnp.ndarray,  # (G, L, F) float — padded to one fleet bucket
    esrc: jnp.ndarray,  # (G, E) int
    edst: jnp.ndarray,  # (G, E) int
    ewords: jnp.ndarray,  # (G, E) float
    src_mask: jnp.ndarray,  # (G, L) bool
    sink_mask: jnp.ndarray,  # (G, L) bool
    cuts_batch: jnp.ndarray,  # (G, C, E) bool
    hw_rows: jnp.ndarray,  # (H, 11) float — shared across the fleet
    area_consts: jnp.ndarray,  # (4,) float
    node_mask: jnp.ndarray,  # (G, L) bool
    edge_mask: jnp.ndarray,  # (G, E) bool
) -> jnp.ndarray:
    """Raw rows for every (graph, hw, grouping) triple -> (G, H, C, 5).

    One more vmap level over :func:`evaluate_batch_graph`: a whole fleet of
    graphs, zero-padded to a common ``(L, E, C)`` bucket
    (:func:`repro.core.ir.pad_graph`), evaluated by a single XLA program —
    the multi-graph sweep pays one compile regardless of fleet size.
    """
    per_graph = jax.vmap(
        _evaluate_batch_graph,
        in_axes=(0, 0, 0, 0, 0, 0, 0, None, None, 0, 0),
    )
    return per_graph(
        feat, esrc, edst, ewords, src_mask, sink_mask, cuts_batch, hw_rows,
        area_consts, node_mask, edge_mask,
    )


_jit_fleet_graph = jax.jit(_evaluate_fleet_graph)


# Per-mesh jitted shard_map wrappers around the fleet kernel.  Meshes are
# few (one per device layout the process ever sweeps on), so an unbounded
# memo is fine; the AOT executable cache in repro.core.flow is what bounds
# compiled-program memory.
_SHARDED_FLEET_KERNELS: dict = {}


def sharded_fleet_kernel(mesh):
    """The fleet kernel shard_mapped over ``mesh``'s 1-D hardware axis.

    ``hw_rows`` is sharded ``P(axis)`` along H; every other argument is
    replicated; the output keeps its (G, H, C, 5) logical shape with the H
    axis laid out across devices (``P(None, axis)``), so fetching the
    result is the one cross-device gather of the sweep.  Each device runs
    :func:`_evaluate_fleet_graph` on its H-shard — per-row arithmetic is
    identical to the single-device program (rows are vmapped independently;
    no cross-row reduction exists to reassociate), which is why the sharded
    sweep is bit-identical, not just close (asserted in
    tests/test_multidevice.py at 2 and 8 host devices).

    Callers must pad H to a multiple of the device count first
    (:func:`repro.core.flow.run_fleet` pads with copies of row 0 and slices
    the padded rows off before metrics composition — the PR 4 inert-padding
    idiom applied to the hardware axis).
    """
    from ..parallel.sharding import HW_AXIS, mesh_fingerprint, shard_map_fn

    key = mesh_fingerprint(mesh)
    fn = _SHARDED_FLEET_KERNELS.get(key)
    if fn is None:
        from jax.sharding import PartitionSpec as P

        repl = P()
        fn = jax.jit(
            shard_map_fn()(
                _evaluate_fleet_graph,
                mesh=mesh,
                in_specs=(repl,) * 7 + (P(HW_AXIS), repl, repl, repl),
                out_specs=P(None, HW_AXIS),
            )
        )
        _SHARDED_FLEET_KERNELS[key] = fn
    return fn


def area_consts_of_space(config_space) -> np.ndarray:
    """Shared area constants of a config space, validating they ARE shared.

    The sweep kernels take one ``area_consts`` vector for the whole
    hardware batch (only row fields vary per config), so a space mixing
    area calibrations would silently evaluate every config under
    ``config_space[0]``'s constants — reject it instead."""
    consts = {
        (
            c.area_per_mult_um2,
            c.area_per_pe_overhead_um2,
            c.area_per_sram_byte_um2,
            c.area_controller_um2,
        )
        for c in config_space
    }
    if len(consts) != 1:
        raise ConfigValidationError(
            f"config space mixes {len(consts)} area-constant calibrations; "
            "the sweep shares one area_consts vector across the hardware "
            "batch — sweep each calibration separately"
        )
    return area_consts_of(config_space[0])


def pareto_front_mask(rows: np.ndarray) -> np.ndarray:
    """Boolean mask of the Pareto-optimal rows of an (N, M) metric matrix,
    minimising every column.

    A row is kept iff no other row is <= it in every column and < in at
    least one.  Exact-duplicate metric rows keep only their FIRST
    occurrence (lowest index) — the same deterministic lowest-index
    convention as the flow's argmin tie-break, so the front is invariant
    to padding and, up to identical metric rows, to permutation of the
    candidate axes.

    Complexity O(N log N + N * F) where F is the front size (rows are
    scanned in lexicographic order, in which any dominator of a row
    precedes it, so each row is tested against the accumulated front
    only).
    """
    rows = np.atleast_2d(np.asarray(rows, dtype=np.float64))
    n = rows.shape[0]
    mask = np.zeros(n, dtype=bool)
    if n == 0:
        return mask
    uniq, first_idx = np.unique(rows, axis=0, return_index=True)
    front = np.empty_like(uniq)
    k = 0
    for i, r in enumerate(uniq):
        # uniq rows are distinct, so componentwise <= already implies
        # strict dominance somewhere.
        if k and np.all(front[:k] <= r, axis=1).any():
            continue
        front[k] = r
        k += 1
        mask[first_idx[i]] = True
    return mask


def evaluate_fleet_graph(
    feat,
    esrc,
    edst,
    ewords,
    src_mask,
    sink_mask,
    cuts_batch,
    hw_rows,
    area_consts,
    node_mask,
    edge_mask,
) -> np.ndarray:
    """(G, H, C, 4) metrics — scoped-x64 wrapper over the jitted fleet
    kernel (see :func:`evaluate_batch_graph` for the dtype contract)."""
    with enable_x64():
        raw = _jit_fleet_graph(
            feat, esrc, edst, ewords, src_mask, sink_mask, cuts_batch,
            hw_rows, area_consts, node_mask, edge_mask,
        )
    return compose_metrics(raw, hw_rows)


def chain_edge_arrays(feat: np.ndarray):
    """(esrc, edst, ewords, src_mask, sink_mask) for a chain's (L, F) features."""
    L = feat.shape[0]
    esrc = np.arange(L - 1, dtype=np.int64)
    edst = np.arange(1, L, dtype=np.int64)
    ewords = np.asarray(feat[1:, F_IN], dtype=np.float64)
    src_mask = np.zeros(L, dtype=bool)
    src_mask[0] = True
    sink_mask = np.zeros(L, dtype=bool)
    sink_mask[-1] = True
    return esrc, edst, ewords, src_mask, sink_mask


def evaluate_batch(
    feat: jnp.ndarray,  # (L, F) float
    cuts_batch: jnp.ndarray,  # (C, L-1) bool
    hw_rows: jnp.ndarray,  # (H, 11) float
    area_consts: jnp.ndarray,  # (4,) float
) -> jnp.ndarray:
    """Chain-shaped wrapper around :func:`evaluate_batch_graph` -> (H, C, 4)."""
    esrc, edst, ewords, src_mask, sink_mask = chain_edge_arrays(np.asarray(feat))
    return evaluate_batch_graph(
        jnp.asarray(feat),
        jnp.asarray(esrc),
        jnp.asarray(edst),
        jnp.asarray(ewords),
        jnp.asarray(src_mask),
        jnp.asarray(sink_mask),
        jnp.asarray(cuts_batch),
        jnp.asarray(hw_rows),
        jnp.asarray(area_consts),
    )


def area_consts_of(hw: DLAConfig) -> np.ndarray:
    """The per-config area-calibration constants as a feature row."""
    return np.asarray(
        [
            hw.area_per_mult_um2,
            hw.area_per_pe_overhead_um2,
            hw.area_per_sram_byte_um2,
            hw.area_controller_um2,
        ],
        dtype=np.float64,
    )
