"""Evaluation metrics — Eq. (1)-(4) of the paper.

Two implementations, kept deliberately in lock-step (tests assert equality):

* ``*_ref``      — direct, readable transcriptions of the equations operating
  on :class:`repro.core.ir.NetworkIR` + a cut vector.  These are the oracle.
* ``evaluate_batch`` — a vectorised jnp version broadcast over a batch of
  hardware configurations (H) x a batch of fusion groupings (C), so the
  paper's exhaustive optimisation flow (Sec. II-C) runs as ONE jitted XLA
  program instead of a Python loop over ~5 M candidates.

Grouping representation: a boolean *cut vector* ``cuts`` of length ``L-1``;
``cuts[i]`` True means a fusion-group boundary between layer ``i`` and
``i+1``.  Layer-by-layer execution is ``cuts = all True``; whole-network
fusion is ``all False``.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .arch import DLAConfig
from .ir import NetworkIR

# Staging buffer (words) for tiles streamed directly from/to DRAM at group
# edges — a group's first input and last output never need full-frame SRAM.
STAGING_WORDS = 4096.0


def group_masks(cuts: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(start, end) boolean masks of shape (L,) from a cut vector (L-1,)."""
    cuts = np.asarray(cuts, dtype=bool)
    L = cuts.shape[0] + 1
    start = np.concatenate([[True], cuts])
    end = np.concatenate([cuts, [True]])
    assert start.shape == (L,) and end.shape == (L,)
    return start, end


def groups_from_cuts(cuts: np.ndarray) -> list[list[int]]:
    """Explicit group index lists (for printing / brute-force tests)."""
    start, _ = group_masks(cuts)
    groups: list[list[int]] = []
    for i, s in enumerate(start):
        if s:
            groups.append([i])
        else:
            groups[-1].append(i)
    return groups


# ---------------------------------------------------------------------------
# Reference implementations (the paper's equations, verbatim)
# ---------------------------------------------------------------------------


def bandwidth_ref(ir: NetworkIR, cuts: np.ndarray) -> float:
    """Eq. (1): BW = sum_p { sum_q {N Nkh Nkw M}_Lpq + N Nih Niw + Noh Now M }_Lp."""
    start, end = group_masks(cuts)
    bw = 0.0
    for i, l in enumerate(ir.layers):
        bw += l.weight_words  # every layer's weights stream from DRAM
        if start[i]:
            bw += l.in_words  # group input frame read
        if end[i]:
            bw += l.out_words  # group output frame write
    return bw


def latency_ref(ir: NetworkIR, cuts: np.ndarray, hw: DLAConfig) -> float:
    """Eq. (2): L = sum_p { sum_q {t_rd_W + t_PB + t_PL}_Lpq + t_rd_IF + t_wr_OF }_Lp."""
    start, end = group_masks(cuts)
    lat = 0.0
    for i, l in enumerate(ir.layers):
        lat += l.weight_words / hw.dram_words_per_cycle  # t_rd_W
        lat += hw.pe_busy_cycles(  # t_PB
            macs=l.macs,
            n_in=l.n_in,
            n_out=l.n_out,
            kh=l.kh,
            kw=l.kw,
            pixels_out=(l.h_in // l.stride) * (l.w_in // l.stride),
        )
        lat += hw.pipeline_latency  # t_PL
        if start[i]:
            lat += l.in_words / hw.dram_words_per_cycle  # t_rd_IF
        if end[i]:
            lat += l.out_words / hw.dram_words_per_cycle  # t_wr_OF
    return lat


def sram_accesses_ref(ir: NetworkIR) -> float:
    """C_SRAM: every layer operand passes on-chip SRAM exactly once,
    independent of grouping (fusion only changes what *also* touches DRAM)."""
    return float(sum(l.weight_words + l.in_words + l.out_words for l in ir.layers))


def pe_energy_count_ref(ir: NetworkIR, hw: DLAConfig) -> float:
    """C_PE: busy cycles x pe_units (per-PE-cycle or per-block-cycle)."""
    total = 0.0
    for l in ir.layers:
        total += hw.pe_busy_cycles(
            macs=l.macs,
            n_in=l.n_in,
            n_out=l.n_out,
            kh=l.kh,
            kw=l.kw,
            pixels_out=(l.h_in // l.stride) * (l.w_in // l.stride),
        )
    return total * hw.pe_units


# Back-compat alias (pre-calibration name).
pe_block_cycles_ref = pe_energy_count_ref


def energy_ref(ir: NetworkIR, cuts: np.ndarray, hw: DLAConfig) -> float:
    """Eq. (3): E = E_DRAM*C_DRAM + E_SRAM*C_SRAM + E_PB*C_PB   [nJ]."""
    c_dram = bandwidth_ref(ir, cuts)
    c_sram = sram_accesses_ref(ir)
    c_pb = pe_energy_count_ref(ir, hw)
    return hw.e_dram_nj * c_dram + hw.e_sram_nj * c_sram + hw.e_pb_nj * c_pb


def buffer_words_ref(ir: NetworkIR, cuts: np.ndarray) -> tuple[float, float, float]:
    """SRAM sizing (IF, W, OF) in words for Eq. (4).

    Fused intermediates ping-pong between the input and output frame SRAMs;
    group-edge tensors stream through small staging buffers.  Weight SRAM
    holds the largest single layer's kernels.
    """
    start, end = group_masks(cuts)
    if_need, of_need = STAGING_WORDS, STAGING_WORDS
    for i, l in enumerate(ir.layers):
        src = STAGING_WORDS if start[i] else float(ir.layers[i].in_words)
        dst = STAGING_WORDS if end[i] else float(l.out_words)
        if_need = max(if_need, src)
        of_need = max(of_need, dst)
    w_need = max(float(l.weight_words) for l in ir.layers)
    return if_need, w_need, of_need


def area_ref(ir: NetworkIR, cuts: np.ndarray, hw: DLAConfig) -> float:
    """Eq. (4): A = A_PB + A_IFM + A_WB + A_OFM   [um^2]."""
    if_w, w_w, of_w = buffer_words_ref(ir, cuts)
    return hw.area_um2(if_sram_words=if_w, w_sram_words=w_w, of_sram_words=of_w)


@dataclasses.dataclass(frozen=True)
class Metrics:
    bandwidth_words: float
    latency_cycles: float
    energy_nj: float
    area_um2: float

    def meets(self, c) -> bool:
        return (
            self.bandwidth_words <= c.max_bandwidth_words
            and self.latency_cycles <= c.max_latency_cycles
            and self.energy_nj <= c.max_energy_nj
            and self.area_um2 <= c.max_area_um2
        )


def evaluate_ref(ir: NetworkIR, cuts: np.ndarray, hw: DLAConfig) -> Metrics:
    return Metrics(
        bandwidth_words=bandwidth_ref(ir, cuts),
        latency_cycles=latency_ref(ir, cuts, hw),
        energy_nj=energy_ref(ir, cuts, hw),
        area_um2=area_ref(ir, cuts, hw),
    )


# ---------------------------------------------------------------------------
# Vectorised implementation (jnp) — (H configs) x (C groupings) in one program
# ---------------------------------------------------------------------------

# Feature column indices (must match NetworkIR.FEATURES order).
F_W, F_IN, F_OUT, F_OUT_PRE, F_MACS, F_ISPOOL, F_KH, F_KW, F_NIN, F_NOUT, F_PIX = range(11)
# HW row indices (must match DLAConfig.ROW_FIELDS order).
(H_F1, H_F2, H_F3, H_F4, H_MPP, H_DWPC, H_TPL, H_EDRAM, H_ESRAM, H_EPB,
 H_PEU) = range(11)


def _ceil_div(a, b):
    return jnp.ceil(a / b)


def _pe_busy_cycles_vec(feat: jnp.ndarray, hw: jnp.ndarray) -> jnp.ndarray:
    """t_PB per layer, (L,) given one hw row — branch on PE style."""
    co = _ceil_div(feat[:, F_NOUT], hw[H_F1])
    ci = _ceil_div(feat[:, F_NIN], hw[H_F4])
    px_h = _ceil_div(feat[:, F_PIX], hw[H_F2] * hw[H_F3])  # hsiao: F2*F3 pixels
    kc_h = _ceil_div(feat[:, F_KH] * feat[:, F_KW], 9.0)
    px_v = _ceil_div(feat[:, F_PIX], hw[H_F2])  # vwa: F2 rows
    kc_v = feat[:, F_KH] * _ceil_div(feat[:, F_KW], 3.0)
    is_hsiao = hw[H_MPP] == 9
    cyc = jnp.where(is_hsiao, co * ci * px_h * kc_h, co * ci * px_v * kc_v)
    return jnp.where(feat[:, F_MACS] > 0, cyc, 0.0)


def _evaluate_one(feat: jnp.ndarray, cuts: jnp.ndarray, hw: jnp.ndarray,
                  area_consts: jnp.ndarray) -> jnp.ndarray:
    """Metrics for one (grouping, hw) pair -> (4,) [bw, lat, energy, area]."""
    L = feat.shape[0]
    start = jnp.concatenate([jnp.ones((1,), bool), cuts])
    end = jnp.concatenate([cuts, jnp.ones((1,), bool)])

    # Eq. (1)
    bw = (
        jnp.sum(feat[:, F_W])
        + jnp.sum(jnp.where(start, feat[:, F_IN], 0.0))
        + jnp.sum(jnp.where(end, feat[:, F_OUT], 0.0))
    )

    # Eq. (2)
    t_pb = _pe_busy_cycles_vec(feat, hw)
    lat = (
        jnp.sum(feat[:, F_W]) / hw[H_DWPC]
        + jnp.sum(t_pb)
        + L * hw[H_TPL]
        + jnp.sum(jnp.where(start, feat[:, F_IN], 0.0)) / hw[H_DWPC]
        + jnp.sum(jnp.where(end, feat[:, F_OUT], 0.0)) / hw[H_DWPC]
    )

    # Eq. (3)
    c_sram = jnp.sum(feat[:, F_W] + feat[:, F_IN] + feat[:, F_OUT])
    c_pb = jnp.sum(t_pb) * hw[H_PEU]
    energy = hw[H_EDRAM] * bw + hw[H_ESRAM] * c_sram + hw[H_EPB] * c_pb

    # Eq. (4)
    src = jnp.where(start, STAGING_WORDS, feat[:, F_IN])
    dst = jnp.where(end, STAGING_WORDS, feat[:, F_OUT])
    if_need = jnp.maximum(jnp.max(src), STAGING_WORDS)
    of_need = jnp.maximum(jnp.max(dst), STAGING_WORDS)
    w_need = jnp.max(feat[:, F_W])
    a_mult, a_pe_ovh, a_byte, a_ctrl = area_consts
    n_pes = hw[H_F1] * hw[H_F4] * hw[H_F2] * hw[H_F3]
    area = (
        n_pes * (hw[H_MPP] * a_mult + a_pe_ovh)
        + (if_need + w_need + of_need) * a_byte
        + a_ctrl
    )
    return jnp.stack([bw, lat, energy, area])


@partial(jax.jit, static_argnames=())
def evaluate_batch(
    feat: jnp.ndarray,  # (L, F) float
    cuts_batch: jnp.ndarray,  # (C, L-1) bool
    hw_rows: jnp.ndarray,  # (H, 10) float
    area_consts: jnp.ndarray,  # (4,) float
) -> jnp.ndarray:
    """All metrics for every (hw, grouping) pair -> (H, C, 4)."""
    per_cut = jax.vmap(_evaluate_one, in_axes=(None, 0, None, None))
    per_hw = jax.vmap(per_cut, in_axes=(None, None, 0, None))
    return per_hw(feat, cuts_batch, hw_rows, area_consts)


def area_consts_of(hw: DLAConfig) -> np.ndarray:
    return np.asarray(
        [
            hw.area_per_mult_um2,
            hw.area_per_pe_overhead_um2,
            hw.area_per_sram_byte_um2,
            hw.area_controller_um2,
        ],
        dtype=np.float64,
    )
