"""Write-ahead log for the planning service — crash-safe request state.

The planning service (:mod:`repro.core.service`) answers each admitted
request with a typed response, but before this module the answers lived
only in process memory: a crash mid-drain lost every in-flight request and
every already-served plan.  The journal makes the service's externally
visible state *durable and replayable*:

* every admission, tick boundary, response, and cancellation is appended
  to ``wal.jsonl`` as one self-verifying record (sequence number + sha256
  digest over the canonical payload, the
  :mod:`repro.checkpoint.checkpoint` integrity idiom) and fsync'd before
  the service acts on it;
* every ``snapshot_every`` records the full service state is compacted
  into an atomically-committed ``snapshot_<seq>.json`` (tmp + fsync +
  rename, the checkpoint commit idiom), so replay cost stays bounded no
  matter how long the service runs;
* :func:`load` replays snapshot + WAL tail back into plain payloads,
  discarding a torn tail (a record cut mid-write by the crash) but
  refusing silently-corrupted interior records.

Encoding is **bit-exact**: floats round-trip through ``float.hex`` and
numpy arrays through base64 of their raw bytes, so a
:class:`~repro.core.service.PlanResponse` decoded from the journal is
bit-identical to the object that was served before the crash — the
property :meth:`repro.core.service.PlanningService.recover` and the
kill-point tests (tests/test_journal*.py) are built on.

Record types (``RECORD_TYPES``)::

    admit     {rid, request}           request passed admission validation
    tick      {tick, rids}             these requests entered a sweep tick
    response  {rid, response}          a typed response was recorded
    cancel    {rid}                    cancellation was requested

A request with an ``admit`` record but no ``response`` record is, by
definition, *in flight*: recovery re-enqueues exactly that set and re-runs
it, so every request is answered exactly once across the crash.
"""
from __future__ import annotations

import base64
import dataclasses
import hashlib
import json
import os
import pathlib
import threading

import numpy as np

from .arch import Constraints, DLAConfig
from .errors import EvaluatorError, JournalCorrupt
from .ir import EdgeSpec, GraphIR, LayerSpec

RECORD_TYPES = ("admit", "tick", "response", "cancel")

WAL_NAME = "wal.jsonl"
SNAPSHOT_PREFIX = "snapshot_"


# ---------------------------------------------------------------------------
# bit-exact scalar / array / dataclass codecs
# ---------------------------------------------------------------------------


def enc_float(x: float) -> str:
    """Lossless float encoding (``float.hex`` handles inf; nan spelled out
    because ``float.fromhex('nan')`` works but ``float('nan').hex()`` does
    too — keep the explicit spelling for readability in the log)."""
    x = float(x)
    if np.isnan(x):
        return "nan"
    return x.hex()


def dec_float(s: str) -> float:
    """Inverse of :func:`enc_float`."""
    return float.fromhex(s) if s != "nan" else float("nan")


def enc_array(a: np.ndarray) -> dict:
    """Lossless ndarray encoding: dtype + shape + base64 raw bytes."""
    a = np.ascontiguousarray(a)
    return {
        "dtype": str(a.dtype),
        "shape": list(a.shape),
        "data": base64.b64encode(a.tobytes()).decode("ascii"),
    }


def dec_array(d: dict) -> np.ndarray:
    """Inverse of :func:`enc_array`."""
    raw = base64.b64decode(d["data"])
    return np.frombuffer(raw, dtype=np.dtype(d["dtype"])).reshape(
        d["shape"]
    ).copy()


def _init_fields(obj) -> dict:
    """The init= dataclass fields of ``obj`` (derived fields recompute)."""
    return {
        f.name: getattr(obj, f.name)
        for f in dataclasses.fields(obj)
        if f.init
    }


def enc_graph(g: GraphIR) -> dict:
    """GraphIR -> plain dict (LayerSpec/EdgeSpec fields are ints/strs)."""
    return {
        "name": g.name,
        "nodes": [_init_fields(n) for n in g.nodes],
        "edges": [_init_fields(e) for e in g.edges],
    }


def dec_graph(d: dict) -> GraphIR:
    """Inverse of :func:`enc_graph`; ``__post_init__`` re-validates."""
    return GraphIR(
        name=d["name"],
        nodes=tuple(LayerSpec(**n) for n in d["nodes"]),
        edges=tuple(EdgeSpec(**e) for e in d["edges"]),
    )


def enc_config(c: DLAConfig) -> dict:
    """DLAConfig -> plain dict (floats hex-encoded for exactness)."""
    out = {}
    for name, v in _init_fields(c).items():
        out[name] = enc_float(v) if isinstance(v, float) else v
    return out


def dec_config(d: dict) -> DLAConfig:
    """Inverse of :func:`enc_config`."""
    kw = {
        k: dec_float(v) if isinstance(v, str) and k.startswith(("e_", "area"))
        else v
        for k, v in d.items()
    }
    return DLAConfig(**kw)


def enc_constraints(c: Constraints) -> list[str]:
    """Constraints -> four hex floats in metric order."""
    return [enc_float(x) for x in c.as_row()]


def dec_constraints(row: list[str]) -> Constraints:
    """Inverse of :func:`enc_constraints`."""
    return Constraints(*[dec_float(x) for x in row])


# ---------------------------------------------------------------------------
# request / response codecs (the service's durable vocabulary)
# ---------------------------------------------------------------------------


def enc_request(adm) -> dict:
    """Serialise a validated admission (service ``_Admitted``).

    The *remaining* deadline budget is stored rather than the absolute
    monotonic deadline: monotonic clocks do not survive a process, so a
    recovered request's deadline restarts from its recovery time with the
    budget it had at admission.
    """
    return {
        "rid": adm.request_id,
        "graph": enc_graph(adm.g),
        "budget": enc_float(adm.budget),
        "deadline_budget": enc_float(
            adm.deadline - adm.submitted_at
            if np.isfinite(adm.deadline)
            else float("inf")
        ),
        "constraints": enc_constraints(adm.constraints),
        "config_space": [enc_config(c) for c in adm.config_space],
    }


def dec_request(d: dict) -> dict:
    """Decode :func:`enc_request` into plain kwargs (the service rebuilds
    its internal admission entry from these)."""
    return {
        "rid": int(d["rid"]),
        "graph": dec_graph(d["graph"]),
        "budget": dec_float(d["budget"]),
        "deadline_budget": dec_float(d["deadline_budget"]),
        "constraints": dec_constraints(d["constraints"]),
        "config_space": tuple(dec_config(c) for c in d["config_space"]),
    }


def enc_metrics(m) -> list[str]:
    """Metrics -> four hex floats."""
    return [
        enc_float(m.bandwidth_words),
        enc_float(m.latency_cycles),
        enc_float(m.energy_nj),
        enc_float(m.area_um2),
    ]


def enc_plan(plan) -> dict:
    """FlowResult -> plain dict.  ``pareto`` is not journaled (the service
    never sweeps with ``pareto=True``); a plan carrying one is refused
    loudly rather than silently dropped."""
    if plan.pareto is not None:
        raise JournalCorrupt("refusing to journal a plan with a Pareto front")
    return {
        "best_hw": enc_config(plan.best_hw),
        "best_cuts": enc_array(plan.best_cuts),
        "best_metrics": enc_metrics(plan.best_metrics),
        "group_sizes": list(plan.group_sizes),
        "n_candidates": plan.n_candidates,
        "n_feasible": plan.n_feasible,
        "n_pruned": plan.n_pruned,
        "compile_seconds": enc_float(plan.compile_seconds),
        "sweep_seconds": enc_float(plan.sweep_seconds),
        "candidates_per_second": enc_float(plan.candidates_per_second),
        "search_engine": plan.search_engine,
    }


def dec_plan(d: dict):
    """Inverse of :func:`enc_plan`."""
    from . import flow, metrics as M

    bw, lat, e, a = (dec_float(x) for x in d["best_metrics"])
    return flow.FlowResult(
        best_hw=dec_config(d["best_hw"]),
        best_cuts=dec_array(d["best_cuts"]),
        best_metrics=M.Metrics(
            bandwidth_words=bw, latency_cycles=lat, energy_nj=e, area_um2=a
        ),
        group_sizes=tuple(d["group_sizes"]),
        n_candidates=int(d["n_candidates"]),
        n_feasible=int(d["n_feasible"]),
        n_pruned=int(d["n_pruned"]),
        compile_seconds=dec_float(d["compile_seconds"]),
        sweep_seconds=dec_float(d["sweep_seconds"]),
        candidates_per_second=dec_float(d["candidates_per_second"]),
        search_engine=d["search_engine"],
    )


def enc_error(err: EvaluatorError) -> dict:
    """Typed error -> {type, message, attrs}.  ``cause`` chains are kept
    as repr strings (arbitrary exceptions are not replayable objects)."""
    attrs = {}
    if hasattr(err, "min_feasible_budget_words"):
        attrs["min_feasible_budget_words"] = enc_float(
            err.min_feasible_budget_words
        )
    if hasattr(err, "attempts"):
        attrs["attempts"] = int(err.attempts)
    if getattr(err, "cause", None) is not None:
        attrs["cause_repr"] = repr(err.cause)
    return {"type": type(err).__name__, "message": str(err), "attrs": attrs}


def dec_error(d: dict) -> EvaluatorError:
    """Inverse of :func:`enc_error` — resolves the class by name from
    :mod:`repro.core.errors` (falling back to the root type for classes
    defined elsewhere, e.g. ``fusion.FrontierTooWide``)."""
    from . import errors as E

    cls = getattr(E, d["type"], None)
    if cls is None or not (
        isinstance(cls, type) and issubclass(cls, EvaluatorError)
    ):
        cls = EvaluatorError
    attrs = d.get("attrs", {})
    if cls is E.InfeasibleBudgetError:
        err = cls(
            d["message"],
            min_feasible_budget_words=dec_float(
                attrs.get("min_feasible_budget_words", "nan")
            ),
        )
    elif cls is E.TransientFailure:
        err = cls(d["message"], attempts=attrs.get("attempts", 0))
    else:
        err = cls(d["message"])
    return err


def enc_response(resp) -> dict:
    """PlanResponse -> plain dict, bit-exact where it matters (plan
    contents, quality bound); timing floats ride along as-recorded."""
    return {
        "rid": resp.request_id,
        "ok": resp.ok,
        "plan": enc_plan(resp.plan) if resp.plan is not None else None,
        "error": enc_error(resp.error) if resp.error is not None else None,
        "engine": resp.engine,
        "rung": resp.rung,
        "exact": resp.exact,
        "degraded": resp.degraded,
        "quality_bound": enc_float(resp.quality_bound),
        "from_cache": resp.from_cache,
        "latency_seconds": enc_float(resp.latency_seconds),
    }


def dec_response(d: dict):
    """Inverse of :func:`enc_response`."""
    from .service import PlanResponse

    return PlanResponse(
        request_id=int(d["rid"]),
        ok=bool(d["ok"]),
        plan=dec_plan(d["plan"]) if d["plan"] is not None else None,
        error=dec_error(d["error"]) if d["error"] is not None else None,
        engine=d["engine"],
        rung=d["rung"],
        exact=bool(d["exact"]),
        degraded=bool(d["degraded"]),
        quality_bound=dec_float(d["quality_bound"]),
        from_cache=bool(d["from_cache"]),
        latency_seconds=dec_float(d["latency_seconds"]),
    )


# ---------------------------------------------------------------------------
# the write-ahead log
# ---------------------------------------------------------------------------


def record_digest(seq: int, rtype: str, payload: dict) -> str:
    """sha256 over the canonical (seq, type, payload) JSON — the same
    per-item integrity idiom as the checkpoint manifest.  Public: the
    sweep-chunk checkpoint store (:mod:`repro.checkpoint`) digests its
    records through this exact function, so every durable byte in the
    system shares one verification idiom."""
    blob = json.dumps([seq, rtype, payload], sort_keys=True,
                      separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


class Journal:
    """Appender for one service's write-ahead log.

    Records are applied *after* they are durable: the service journals an
    admission before enqueueing it and a response before recording it, so
    the log is always at least as advanced as the in-memory state a crash
    destroys.  ``fsync=False`` is for tests that exercise replay logic
    without paying per-record fsync latency.
    """

    def __init__(self, journal_dir, *, fsync: bool = True,
                 snapshot_every: int = 0):
        """Open (creating if needed) the WAL in ``journal_dir``."""
        self.dir = pathlib.Path(journal_dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.fsync = bool(fsync)
        self.snapshot_every = int(snapshot_every)
        self._seq = _last_seq(self.dir)
        self._since_snapshot = 0
        self._fh = open(self.dir / WAL_NAME, "a", encoding="utf-8")
        # Appends must be serialised: the async transport journals cancel
        # records from the caller thread while the worker journals
        # responses, and the (seq, write, fsync) triple is not atomic.
        self._lock = threading.Lock()

    @property
    def seq(self) -> int:
        """Sequence number of the last appended record (0 = none)."""
        return self._seq

    def append(self, rtype: str, payload: dict) -> int:
        """Durably append one record; returns its sequence number."""
        if rtype not in RECORD_TYPES:
            raise ValueError(f"unknown journal record type {rtype!r}")
        with self._lock:
            self._seq += 1
            rec = {
                "seq": self._seq,
                "type": rtype,
                "payload": payload,
                "digest": record_digest(self._seq, rtype, payload),
            }
            self._fh.write(json.dumps(rec, separators=(",", ":")) + "\n")
            self._fh.flush()
            if self.fsync:
                os.fsync(self._fh.fileno())
            self._since_snapshot += 1
            return self._seq

    def maybe_snapshot(self, state_payload_fn) -> bool:
        """Write a snapshot if ``snapshot_every`` records accumulated since
        the last one.  ``state_payload_fn`` is called only when a snapshot
        is actually due (building the payload is not free)."""
        if not self.snapshot_every:
            return False
        if self._since_snapshot < self.snapshot_every:
            return False
        self.snapshot(state_payload_fn())
        return True

    def snapshot(self, state_payload: dict) -> pathlib.Path:
        """Atomically commit a compacted state snapshot at the current
        sequence number (tmp + fsync + rename, the checkpoint idiom), then
        drop WAL records the snapshot supersedes by rewriting the WAL with
        only the tail.  A crash at any point leaves either the old state
        or the new one, never a mix."""
        with self._lock:
            return self._snapshot_locked(state_payload)

    def _snapshot_locked(self, state_payload: dict) -> pathlib.Path:
        seq = self._seq
        body = {
            "seq": seq,
            "state": state_payload,
        }
        body["digest"] = record_digest(seq, "snapshot", state_payload)
        final = self.dir / f"{SNAPSHOT_PREFIX}{seq:012d}.json"
        tmp = self.dir / f"{SNAPSHOT_PREFIX}{seq:012d}.json.tmp"
        tmp.write_text(json.dumps(body, separators=(",", ":")))
        with open(tmp, "rb") as f:
            os.fsync(f.fileno())
        tmp.rename(final)  # atomic commit
        # Compact: the WAL only needs records after the snapshot.  The
        # snapshot is already durable, so a crash mid-rewrite loses nothing
        # (replay = snapshot + whatever tail survives).
        self._fh.close()
        tail = [
            r for r in _read_wal(self.dir, allow_torn_tail=False)
            if r["seq"] > seq
        ]
        wal_tmp = self.dir / (WAL_NAME + ".tmp")
        with open(wal_tmp, "w", encoding="utf-8") as f:
            for r in tail:
                f.write(json.dumps(r, separators=(",", ":")) + "\n")
            f.flush()
            os.fsync(f.fileno())
        wal_tmp.rename(self.dir / WAL_NAME)
        for old in sorted(self.dir.glob(f"{SNAPSHOT_PREFIX}*.json"))[:-1]:
            old.unlink()
        self._fh = open(self.dir / WAL_NAME, "a", encoding="utf-8")
        self._since_snapshot = 0
        return final

    def close(self) -> None:
        """Flush and close the WAL file handle."""
        with self._lock:
            if not self._fh.closed:
                self._fh.flush()
                if self.fsync:
                    os.fsync(self._fh.fileno())
                self._fh.close()


def _read_wal(journal_dir, *, allow_torn_tail: bool) -> list[dict]:
    """Parse ``wal.jsonl`` into verified records.

    A *torn tail* — the final line truncated or digest-broken, exactly
    what a crash mid-append produces — is discarded when allowed.  A bad
    record with valid records AFTER it is not a crash artifact but real
    corruption, and raises :class:`JournalCorrupt` (never silently skip an
    interior record: the replayed state would be wrong)."""
    path = pathlib.Path(journal_dir) / WAL_NAME
    if not path.exists():
        return []
    records: list[dict] = []
    lines = path.read_text(encoding="utf-8").splitlines()
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
            ok = rec.get("digest") == record_digest(
                rec["seq"], rec["type"], rec["payload"]
            )
        except (json.JSONDecodeError, KeyError, TypeError):
            ok = False
        if not ok:
            if i == len(lines) - 1 and allow_torn_tail:
                break  # crash tore the final append — drop it
            raise JournalCorrupt(
                f"{path}: corrupt record at line {i + 1} "
                f"({len(lines) - 1 - i} valid records follow it)"
            )
        records.append(rec)
    return records


def _last_seq(journal_dir) -> int:
    """Highest durable sequence number (snapshot or WAL), 0 when empty."""
    snap = latest_snapshot(journal_dir)
    seq = snap["seq"] if snap is not None else 0
    recs = _read_wal(journal_dir, allow_torn_tail=True)
    return max([seq] + [r["seq"] for r in recs])


def latest_snapshot(journal_dir) -> dict | None:
    """The newest verified snapshot body, or None.  An unverifiable
    snapshot (torn mid-write before the atomic rename — impossible — or
    bit-rotted after) raises :class:`JournalCorrupt`."""
    path = pathlib.Path(journal_dir)
    if not path.exists():
        return None
    snaps = sorted(path.glob(f"{SNAPSHOT_PREFIX}*.json"))
    if not snaps:
        return None
    body = json.loads(snaps[-1].read_text())
    if body.get("digest") != record_digest(body["seq"], "snapshot", body["state"]):
        raise JournalCorrupt(f"{snaps[-1]}: snapshot digest mismatch")
    return body


def load(journal_dir) -> tuple[dict | None, list[dict]]:
    """Replay a journal directory: (snapshot_state | None, wal_records).

    ``wal_records`` contains only records newer than the snapshot, in
    sequence order, with the torn tail (if any) dropped.  Gaps in the
    sequence raise :class:`JournalCorrupt` — a missing interior record
    means the log cannot be trusted."""
    snap = latest_snapshot(journal_dir)
    base_seq = snap["seq"] if snap is not None else 0
    records = [
        r for r in _read_wal(journal_dir, allow_torn_tail=True)
        if r["seq"] > base_seq
    ]
    expect = base_seq
    for r in records:
        expect += 1
        if r["seq"] != expect:
            raise JournalCorrupt(
                f"journal sequence gap: expected {expect}, got {r['seq']}"
            )
    return (snap["state"] if snap is not None else None), records


# Back-compat alias for the pre-public name (tests and older callers).
_digest = record_digest
